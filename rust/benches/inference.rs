//! Inference-engine benchmarks.
//!
//! The headline section needs **no artifacts**: it synthesizes a
//! resnet20 encrypted bundle and measures the packed parallel fused
//! engine (`InferenceModel::forward`) against the pre-engine scalar
//! separate-pass composition (`forward_reference`), the bit-plane and
//! decrypt-on-demand Encrypted engines on the same bundle (including
//! the sub-1-bit `resident_bits_per_weight` record and the
//! encrypted-vs-bitplane forward overhead), plus raw packed-GEMM thread
//! scaling. Results — op, shape, ns/iter, threads, throughput and the
//! headline speedup — are merged into `BENCH_infer.json` so the perf
//! trajectory is tracked across PRs (`--quick` for the CI smoke mode).
//!
//! With `make artifacts` present, the original trained-bundle section
//! (bundle load/decrypt time + per-model forward latency) also runs.

use std::path::Path;

use flexor::coordinator::{
    export_bundle, export_synthetic_resnet_bundle, MetricsSink, Schedule, TrainSession,
};
use flexor::data::{self, Batcher, Split};
use flexor::inference::bitslice::popcount::{self, Kernel};
use flexor::inference::bitslice::{self, PlaneStore};
use flexor::inference::gemm::{gemm_packed_into, Epilogue, PackedB};
use flexor::inference::{ComputeMode, InferenceModel};
use flexor::runtime::{Manifest, Runtime};
use flexor::substrate::bench::{black_box, merge_bench_history, merge_bench_json, Bench, CaseMeta};
use flexor::substrate::json::Json;
use flexor::substrate::pool::{self, ThreadPool};
use flexor::substrate::prng::Pcg32;
use flexor::substrate::trace;

/// Intra-op budget for the headline forward numbers (the acceptance
/// configuration: batch 8, 4 threads).
const THREADS: usize = 4;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let mut b = if quick { Bench::quick() } else { Bench::new() };
    pool::configure_global(THREADS);

    // ---- packed engine vs scalar reference (synthetic resnet20) ----------
    let dir = std::env::temp_dir().join(format!("flexor_infer_bench_{}", std::process::id()));
    let hw = 16usize;
    let batch = 8usize;
    export_synthetic_resnet_bundle(&dir, "rn20", 17, "resnet20", hw, 10)
        .expect("synthetic resnet20 bundle");
    let model = InferenceModel::load(&dir, "rn20").expect("bundle load");
    let mut rng = Pcg32::seeded(7);
    let feat = hw * hw * 3;
    let xs: Vec<f32> = (0..batch * feat).map(|_| rng.normal()).collect();
    let shape = format!("{batch}x{hw}x{hw}x3");

    println!("# resnet20 synthetic bundle (input {hw}x{hw}x3)\n");
    let slow = b
        .run_case(
            &format!("forward scalar-reference/resnet20 batch={batch}"),
            Some(CaseMeta::new("forward_reference_scalar", &shape, 1)),
            Some(batch as f64),
            "ex",
            || {
                black_box(model.forward_reference(black_box(&xs), batch).unwrap());
            },
        )
        .mean_s;
    let threads = pool::global().threads();
    let fast = b
        .run_case(
            &format!("forward packed-fused/resnet20 batch={batch} threads={threads}"),
            Some(CaseMeta::new("forward_packed_fused", &shape, threads)),
            Some(batch as f64),
            "ex",
            || {
                black_box(model.forward(black_box(&xs), batch).unwrap());
            },
        )
        .mean_s;
    let single = format!("1x{hw}x{hw}x3");
    b.run_case(
        &format!("forward packed-fused/resnet20 batch=1 threads={threads}"),
        Some(CaseMeta::new("forward_packed_fused", &single, threads)),
        Some(1.0),
        "ex",
        || {
            black_box(model.forward(black_box(&xs[..feat]), 1).unwrap());
        },
    );
    let speedup = slow / fast;
    println!("\nspeedup packed-fused vs scalar-reference (batch {batch}): {speedup:.2}x");

    // ---- bit-plane engine on the same bundle (DESIGN.md §8) ---------------
    println!("\n# resnet20 bit-plane engine (same bundle, packed bit-planes)\n");
    let act_planes = bitslice::DEFAULT_ACT_PLANES;
    let bp_model = InferenceModel::load_with_mode(
        &dir,
        "rn20",
        ComputeMode::BitPlane { act_planes },
    )
    .expect("bundle load (bitplane)");
    let bp = b
        .run_case(
            &format!("forward bitplane/resnet20 batch={batch} threads={threads}"),
            Some(CaseMeta::new("forward_bitplane", &shape, threads)),
            Some(batch as f64),
            "ex",
            || {
                black_box(bp_model.forward(black_box(&xs), batch).unwrap());
            },
        )
        .mean_s;
    println!(
        "\nbitplane vs packed-fused forward (batch {batch}): {:.2}x packed time",
        bp / fast
    );

    // forward simd A/B: pin the scalar popcount kernel, then return to
    // auto — kernels are bit-identical, so only speed changes
    popcount::set_override(Some(Kernel::Scalar));
    let bp_scalar = b
        .run_case(
            &format!("forward bitplane kernel=scalar/resnet20 batch={batch} threads={threads}"),
            Some(CaseMeta::new("forward_bitplane_scalar", &shape, threads)),
            Some(batch as f64),
            "ex",
            || {
                black_box(bp_model.forward(black_box(&xs), batch).unwrap());
            },
        )
        .mean_s;
    popcount::set_override(None);
    let active_kernel = popcount::active();
    let fwd_simd_speedup = bp_scalar / bp;
    println!(
        "bitplane forward {} vs scalar kernel: {fwd_simd_speedup:.2}x",
        active_kernel.label()
    );
    // ---- decrypt-on-demand engine (DESIGN.md §11) -------------------------
    // same bundle, encrypted words stay resident and panels decrypt
    // inside the GEMM tile loop — bit-identical logits, sub-1-bit
    // residency, per-forward decrypt overhead measured against bitplane
    println!("\n# resnet20 encrypted engine (decrypt-on-demand tiles)\n");
    let enc_model = InferenceModel::load_with_mode(
        &dir,
        "rn20",
        ComputeMode::Encrypted { act_planes },
    )
    .expect("bundle load (encrypted)");
    let enc = b
        .run_case(
            &format!("forward encrypted/resnet20 batch={batch} threads={threads}"),
            Some(CaseMeta::new("forward_encrypted", &shape, threads)),
            Some(batch as f64),
            "ex",
            || {
                black_box(enc_model.forward(black_box(&xs), batch).unwrap());
            },
        )
        .mean_s;
    let enc_overhead = enc / bp;
    println!(
        "\nencrypted vs bitplane forward (batch {batch}): {enc_overhead:.2}x bitplane time"
    );
    let resident_bpw = enc_model.resident_bits_per_weight();
    println!(
        "encrypted resident rate: {resident_bpw:.4} bits/weight (quantized layers)"
    );

    // per-bundle resident-bytes records: the memory the three engines keep
    let mut resident_records: Vec<Json> = Vec::new();
    for (mode_model, mode_name) in
        [(&model, "dense"), (&bp_model, "bitplane"), (&enc_model, "encrypted")]
    {
        let q = mode_model.quantized_resident_bytes();
        let fp = mode_model.fp_resident_bytes();
        println!(
            "resident bytes {mode_name:9}: quantized {q:>9}  fp residue {fp:>9}"
        );
        resident_records.push(Json::obj(vec![
            ("name", Json::str(format!("resident bytes resnet20 {mode_name}"))),
            ("op", Json::str("resident_bytes")),
            ("shape", Json::str("resnet20")),
            ("mode", Json::str(mode_name)),
            ("quantized_bytes", Json::num(q as f64)),
            ("fp_bytes", Json::num(fp as f64)),
            ("total_bytes", Json::num((q + fp) as f64)),
            ("resident_bits_per_weight", Json::num(mode_model.resident_bits_per_weight())),
        ]));
    }
    let mem_ratio = model.quantized_resident_bytes() as f64
        / bp_model.quantized_resident_bytes().max(1) as f64;
    println!("quantized-layer memory ratio dense/bitplane: {mem_ratio:.1}x");

    // ---- stage-tracing overhead A/B (observability contract, §10) ---------
    // tracing must be free when off and cheap when sampled; the ratio is
    // tracked in BENCH_infer.json as overhead_trace_sampled_vs_off
    println!("\n# stage-tracing overhead (forward packed-fused batch={batch})\n");
    let trace_off = b
        .run_case(
            &format!("forward trace=off/resnet20 batch={batch} threads={threads}"),
            Some(CaseMeta::new("forward_trace_off", &shape, threads)),
            Some(batch as f64),
            "ex",
            || {
                let _t = trace::scope_with(trace::TraceMode::Off, None);
                black_box(model.forward(black_box(&xs), batch).unwrap());
            },
        )
        .mean_s;
    let profile = std::sync::Arc::new(trace::Profile::new());
    let trace_sampled = b
        .run_case(
            &format!("forward trace=sample:8/resnet20 batch={batch} threads={threads}"),
            Some(CaseMeta::new("forward_trace_sampled", &shape, threads)),
            Some(batch as f64),
            "ex",
            || {
                let _t = trace::scope_with(trace::TraceMode::Sample(8), Some(profile.clone()));
                black_box(model.forward(black_box(&xs), batch).unwrap());
            },
        )
        .mean_s;
    let trace_overhead = trace_sampled / trace_off;
    println!(
        "\ntrace sample:8 vs off: {trace_overhead:.3}x ({} forwards traced)",
        profile.traced_forwards()
    );

    // ---- raw packed-GEMM thread scaling (conv-shaped problem) -------------
    println!("\n# packed GEMM thread scaling\n");
    let (m, k, n) = (1024usize, 288usize, 32usize);
    let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
    let wmat: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
    let packed = PackedB::pack(&wmat, k, n);
    let gemm_shape = format!("{m}x{k}x{n}");
    b.run_case(
        &format!("gemm scalar-blocked {gemm_shape}"),
        Some(CaseMeta::new("gemm_scalar", &gemm_shape, 1)),
        Some((m * k * n) as f64),
        "mac",
        || {
            black_box(flexor::inference::tensor::gemm(&a, m, k, &wmat, n));
        },
    );
    let mut c = vec![0.0f32; m * n];
    for threads in [1usize, 2, 4] {
        let p = ThreadPool::new(threads);
        b.run_case(
            &format!("gemm packed {gemm_shape} threads={threads}"),
            Some(CaseMeta::new("gemm_packed", &gemm_shape, threads)),
            Some((m * k * n) as f64),
            "mac",
            || {
                gemm_packed_into(&p, &a, m, k, &packed, Epilogue::None, &mut c);
                black_box(&c);
            },
        );
    }

    // bit-plane GEMM on the same conv-shaped problem (binarize + XNOR /
    // popcount — the true per-layer cost of BitPlane mode), vs packed FP
    println!("\n# bit-plane GEMM thread scaling (q=1, {act_planes} act planes)\n");
    let plane: Vec<f32> = (0..k * n)
        .map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 })
        .collect();
    let alpha: Vec<f32> = (0..n).map(|_| rng.range_f32(0.05, 0.5)).collect();
    let store = PlaneStore::from_sign_planes(&[k, n], &[plane], &[alpha])
        .expect("bench plane store");
    for threads in [1usize, 2, 4] {
        let p = ThreadPool::new(threads);
        b.run_case(
            &format!("gemm bitplane {gemm_shape} threads={threads}"),
            Some(CaseMeta::new("gemm_bitplane", &gemm_shape, threads)),
            Some((m * k * n) as f64),
            "mac",
            || {
                let acts = bitslice::binarize::binarize_rows(&p, &a, m, k, act_planes);
                bitslice::xnor_gemm_into(&p, &acts, &store, Epilogue::None, &mut c);
                acts.recycle();
                black_box(&c);
            },
        );
    }

    // popcount kernel A/B on the same problem — binarize hoisted out of
    // the timed region so the record isolates the XNOR GEMM itself.
    // Kernel::Scalar is the PR 4-style word-at-a-time baseline.
    println!("\n# bit-plane GEMM popcount kernels (threads={THREADS})\n");
    let pk = ThreadPool::new(THREADS);
    let acts = bitslice::binarize::binarize_rows(&pk, &a, m, k, act_planes);
    let mut kernel_times: Vec<(Kernel, f64)> = Vec::new();
    for kern in popcount::available() {
        let t = b
            .run_case(
                &format!("gemm bitplane kernel={} {gemm_shape} threads={THREADS}", kern.label()),
                Some(CaseMeta::new(
                    &format!("gemm_bitplane_{}", kern.label()),
                    &gemm_shape,
                    THREADS,
                )),
                Some((m * k * n) as f64),
                "mac",
                || {
                    bitslice::xnor_gemm_into_with_kernel(
                        &pk,
                        &acts,
                        &store,
                        kern,
                        Epilogue::None,
                        &mut c,
                    );
                    black_box(&c);
                },
            )
            .mean_s;
        kernel_times.push((kern, t));
    }
    acts.recycle();
    let scalar_t = kernel_times
        .iter()
        .find(|(kk, _)| *kk == Kernel::Scalar)
        .map(|(_, t)| *t)
        .expect("scalar kernel is always available");
    let (best_kernel, best_t) = kernel_times
        .iter()
        .copied()
        .min_by(|x, y| x.1.partial_cmp(&y.1).unwrap())
        .unwrap();
    let gemm_simd_speedup = scalar_t / best_t;
    println!(
        "\nbitplane GEMM best kernel ({}) vs scalar word-at-a-time: {gemm_simd_speedup:.2}x",
        best_kernel.label()
    );
    std::fs::remove_dir_all(&dir).ok();

    // ---- trained-bundle section (needs `make artifacts`) ------------------
    let root = Path::new("artifacts");
    if root.join("manifest.json").exists() {
        bench_trained_bundles(&mut b, root);
    } else {
        println!("\nSKIP trained-bundle section: run `make artifacts` first");
    }

    // machine-readable trajectory: BENCH_infer.json (merged by source)
    let all = b.to_json();
    let mut records: Vec<Json> = all.as_arr().unwrap_or(&[]).to_vec();
    records.push(Json::obj(vec![
        ("name", Json::str("speedup packed-fused vs scalar-reference")),
        ("op", Json::str("speedup_forward_resnet20")),
        ("shape", Json::str(shape.clone())),
        ("threads", Json::num(threads as f64)),
        ("speedup", Json::num(speedup)),
    ]));
    records.extend(resident_records);
    records.push(Json::obj(vec![
        ("name", Json::str("quantized memory ratio dense/bitplane resnet20")),
        ("op", Json::str("memory_ratio_dense_over_bitplane")),
        ("shape", Json::str("resnet20")),
        ("ratio", Json::num(mem_ratio)),
    ]));
    records.push(Json::obj(vec![
        ("name", Json::str("speedup bitplane gemm simd vs scalar word-at-a-time")),
        ("op", Json::str("speedup_gemm_bitplane_simd_vs_scalar")),
        ("shape", Json::str(gemm_shape.clone())),
        ("threads", Json::num(THREADS as f64)),
        ("kernel", Json::str(best_kernel.label())),
        ("speedup", Json::num(gemm_simd_speedup)),
    ]));
    records.push(Json::obj(vec![
        ("name", Json::str("speedup bitplane forward simd vs scalar")),
        ("op", Json::str("speedup_forward_bitplane_simd_vs_scalar")),
        ("shape", Json::str(shape.clone())),
        ("threads", Json::num(threads as f64)),
        ("kernel", Json::str(active_kernel.label())),
        ("speedup", Json::num(fwd_simd_speedup)),
    ]));
    records.push(Json::obj(vec![
        ("name", Json::str("overhead trace sampled vs off")),
        ("op", Json::str("overhead_trace_sampled_vs_off")),
        ("shape", Json::str(shape.clone())),
        ("threads", Json::num(threads as f64)),
        ("ratio", Json::num(trace_overhead)),
    ]));
    // the decrypt-on-demand headline pair: sub-1-bit residency and the
    // per-forward price paid for it (resnet20 amortizes the XOR-network
    // overhead below 1 bit/weight; tiny fixtures like resnet8 do not)
    records.push(Json::obj(vec![
        ("name", Json::str("resident bits per weight encrypted resnet20")),
        ("op", Json::str("resident_bits_per_weight_encrypted")),
        ("shape", Json::str("resnet20")),
        ("mode", Json::str("encrypted")),
        ("bits_per_weight", Json::num(resident_bpw)),
    ]));
    records.push(Json::obj(vec![
        ("name", Json::str("overhead forward encrypted vs bitplane")),
        ("op", Json::str("overhead_forward_encrypted_vs_bitplane")),
        ("shape", Json::str(shape.clone())),
        ("threads", Json::num(threads as f64)),
        ("ratio", Json::num(enc_overhead)),
    ]));
    let records = Json::arr(records);
    merge_bench_json(Path::new("BENCH_infer.json"), "inference", records.clone())
        .expect("writing BENCH_infer.json");
    merge_bench_history("inference", records).expect("writing bench_history snapshot");
    println!("\nwrote BENCH_infer.json (source=inference, mirrored to bench_history/)");
}

fn bench_trained_bundles(b: &mut Bench, root: &Path) {
    let rt = Runtime::cpu().unwrap();
    let man = Manifest::load(root).unwrap();

    for (cfg, dataset) in [("quickstart_mlp", "digits"), ("e2e_resnet14_f08", "shapes32")] {
        if !man.configs.contains_key(cfg) {
            continue;
        }
        println!("\n# {cfg}\n");
        let mut session = TrainSession::new(&rt, &man, cfg).unwrap();
        let ds = data::by_name(dataset, 0).unwrap();
        let sched = Schedule::mnist(1e-3, 50);
        let mut sink = MetricsSink::new();
        session.train_loop(ds.as_ref(), &sched, 5, 5, 64, &mut sink).unwrap();
        let dir = std::env::temp_dir().join("flexor_bench_bundle");
        export_bundle(&session, &dir, cfg).unwrap();

        b.run(&format!("bundle-load+decrypt/{cfg}"), || {
            black_box(InferenceModel::load(&dir, cfg).unwrap());
        });

        let model = InferenceModel::load(&dir, cfg).unwrap();
        let threads = pool::global().threads();
        for batch in [1usize, 16, 64] {
            let (xs, _) = Batcher::eval_set(ds.as_ref(), Split::Test, batch);
            b.run_case(
                &format!("forward/{cfg} batch={batch}"),
                Some(CaseMeta::new("forward_packed_fused", &format!("{cfg} batch={batch}"), threads)),
                Some(batch as f64),
                "example",
                || {
                    black_box(model.forward(black_box(&xs), batch).unwrap());
                },
            );
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
