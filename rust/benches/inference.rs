//! Inference-engine benchmarks: bundle load (decrypt) time and forward-pass
//! latency/throughput of the pure-Rust binary-code engine, per model.
//!
//! Needs `make artifacts` (default set). Trains a handful of steps only —
//! the numbers of interest are systems-side, not accuracy.

use std::path::Path;

use flexor::coordinator::{export_bundle, MetricsSink, Schedule, TrainSession};
use flexor::data::{self, Batcher, Split};
use flexor::inference::InferenceModel;
use flexor::runtime::{Manifest, Runtime};
use flexor::substrate::bench::{black_box, Bench};

fn main() {
    let root = Path::new("artifacts");
    if !root.join("manifest.json").exists() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    let quick = std::env::args().any(|a| a == "--quick");
    let mut b = if quick { Bench::quick() } else { Bench::new() };
    let rt = Runtime::cpu().unwrap();
    let man = Manifest::load(root).unwrap();

    for (cfg, dataset) in [("quickstart_mlp", "digits"), ("e2e_resnet14_f08", "shapes32")] {
        if !man.configs.contains_key(cfg) {
            continue;
        }
        println!("\n# {cfg}\n");
        let mut session = TrainSession::new(&rt, &man, cfg).unwrap();
        let ds = data::by_name(dataset, 0).unwrap();
        let sched = Schedule::mnist(1e-3, 50);
        let mut sink = MetricsSink::new();
        session.train_loop(ds.as_ref(), &sched, 5, 5, 64, &mut sink).unwrap();
        let dir = std::env::temp_dir().join("flexor_bench_bundle");
        export_bundle(&session, &dir, cfg).unwrap();

        b.run(&format!("bundle-load+decrypt/{cfg}"), || {
            black_box(InferenceModel::load(&dir, cfg).unwrap());
        });

        let model = InferenceModel::load(&dir, cfg).unwrap();
        for batch in [1usize, 16, 64] {
            let (xs, _) = Batcher::eval_set(ds.as_ref(), Split::Test, batch);
            b.run_with_throughput(
                &format!("forward/{cfg} batch={batch}"),
                Some(batch as f64),
                "example",
                || {
                    black_box(model.forward(black_box(&xs), batch).unwrap());
                },
            );
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    std::fs::create_dir_all("runs").ok();
    std::fs::write("runs/bench_inference.json", b.to_json().to_string_pretty()).ok();
    println!("\nwrote runs/bench_inference.json");
}
