//! Integration tests for the bit-plane XNOR/popcount compute engine
//! (DESIGN.md §8/§9): whole-bundle equivalence against the binarized
//! reference composition, per-layer mixed-mode policies, serving-path
//! agreement between DenseF32 and BitPlane entries of one registry, and
//! the resident-bytes / layer-mode accounting `GET /models` reports.
//! (Cross-engine × kernel × thread bit-identity lives in the generated
//! matrix in `tests/engines.rs`.)

use std::path::PathBuf;

use flexor::coordinator::{export_synthetic_mlp_bundle, export_synthetic_resnet_bundle};
use flexor::inference::{ComputeMode, InferenceModel, ModePolicy};
use flexor::serve::{http, Registry, ServeConfig, Server};
use flexor::substrate::json::{self, Json};
use flexor::substrate::pool::ThreadPool;
use flexor::substrate::prng::Pcg32;

fn bundle_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("flexor_bitslice_{tag}_{}", std::process::id()))
}

fn close(a: f32, b: f32, tol: f32) -> bool {
    (a - b).abs() <= tol * (1.0 + b.abs())
}

/// Satellite: whole-bundle property — the bit-plane forward must match
/// `forward_reference` (which applies the identical activation
/// binarization contract, then dense math) across 1/2/4 pool threads,
/// and must be bit-identical across those thread counts.
#[test]
fn bitplane_forward_matches_binarized_reference_across_threads() {
    let pools = [ThreadPool::new(1), ThreadPool::new(2), ThreadPool::new(4)];
    let mut rng = Pcg32::seeded(501);

    // mlp bundle
    let dir = bundle_dir("ref_mlp");
    let d_in = 16usize;
    export_synthetic_mlp_bundle(&dir, "m", 31, d_in, &[40, 24], 10).unwrap();
    let mlp =
        InferenceModel::load_with_mode(&dir, "m", ComputeMode::BitPlane { act_planes: 6 })
            .unwrap();
    let x: Vec<f32> = (0..5 * d_in).map(|_| rng.normal()).collect();
    let reference = mlp.forward_reference(&x, 5).unwrap();
    let mut first: Option<Vec<f32>> = None;
    for pool in &pools {
        let got = mlp.forward_with_pool(&x, 5, pool).unwrap();
        for (i, (a, b)) in got.iter().zip(&reference).enumerate() {
            assert!(
                close(*a, *b, 1e-3),
                "mlp logit {i} (threads {}): engine {a} vs reference {b}",
                pool.threads()
            );
        }
        match &first {
            None => first = Some(got),
            Some(f) => assert_eq!(*f, got, "mlp: thread count changed the bits"),
        }
    }
    std::fs::remove_dir_all(&dir).ok();

    // conv-heavy resnet bundle. The engine and the reference chain their
    // own layer outputs, so tiny FP differences can land near a
    // binarization threshold and re-quantize differently — the layer-level
    // property tests pin tight tolerances; here 1e-2 guards the algebra.
    let dir = bundle_dir("ref_resnet");
    export_synthetic_resnet_bundle(&dir, "r", 32, "resnet8", 8, 10).unwrap();
    let resnet =
        InferenceModel::load_with_mode(&dir, "r", ComputeMode::BitPlane { act_planes: 8 })
            .unwrap();
    let feat = 8 * 8 * 3;
    let x: Vec<f32> = (0..2 * feat).map(|_| rng.normal()).collect();
    let reference = resnet.forward_reference(&x, 2).unwrap();
    assert_eq!(reference.len(), 2 * 10);
    let mut first: Option<Vec<f32>> = None;
    for pool in &pools {
        let got = resnet.forward_with_pool(&x, 2, pool).unwrap();
        for (i, (a, b)) in got.iter().zip(&reference).enumerate() {
            assert!(a.is_finite(), "resnet logit {i} not finite: {a}");
            assert!(
                close(*a, *b, 1e-2),
                "resnet logit {i} (threads {}): engine {a} vs reference {b}",
                pool.threads()
            );
        }
        match &first {
            None => first = Some(got),
            Some(f) => assert_eq!(*f, got, "resnet: thread count changed the bits"),
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Satellite: a mixed per-layer policy runs small layers dense and big
/// layers on bit-planes, labels itself `mixed`, reports per-layer modes
/// over `GET /models`, and sits between the pure modes in resident
/// bytes. (That a degenerate threshold policy IS the dense engine,
/// bit for bit, is pinned by the matrix in `tests/engines.rs`.)
#[test]
fn mixed_mode_policy_assigns_layers_and_serves() {
    let dir = bundle_dir("mixed");
    export_synthetic_resnet_bundle(&dir, "rn", 44, "resnet8", 8, 10).unwrap();
    const THRESHOLD: usize = 2000;
    let policy = ModePolicy::parse("bitplane:24@min=2000,0=dense").unwrap();
    let mixed = InferenceModel::load_with_policy(&dir, "rn", policy.clone()).unwrap();
    assert_eq!(mixed.mode_label(), "mixed");
    let lm = mixed.layer_modes();
    assert!(lm.iter().any(|l| l.mode.is_bit_plane()), "no bit-plane layers");
    assert!(lm.iter().any(|l| !l.mode.is_bit_plane()), "no dense layers");
    assert_eq!(
        lm.iter().find(|l| l.idx == 0).unwrap().mode,
        ComputeMode::DenseF32,
        "explicit override for layer 0 must win"
    );
    for l in &lm {
        if l.idx == 0 {
            continue;
        }
        assert_eq!(
            l.mode.is_bit_plane(),
            l.weights >= THRESHOLD,
            "layer {} ({} weights) on the wrong engine",
            l.idx,
            l.weights
        );
    }

    // an override naming a layer the bundle doesn't have is an operator
    // typo — the load must fail loudly, not silently ignore it
    let bogus = ModePolicy::parse("bitplane,99=dense").unwrap();
    let err = InferenceModel::load_with_policy(&dir, "rn", bogus).unwrap_err();
    assert!(err.to_string().contains("99"), "unhelpful error: {err}");

    // resident bytes: pure dense ≥ mixed ≥ pure bitplane
    let dense = InferenceModel::load(&dir, "rn").unwrap();
    let bp = InferenceModel::load_with_mode(
        &dir,
        "rn",
        ComputeMode::BitPlane { act_planes: 24 },
    )
    .unwrap();
    let (qd, qm, qb) = (
        dense.quantized_resident_bytes(),
        mixed.quantized_resident_bytes(),
        bp.quantized_resident_bytes(),
    );
    assert!(qd > qm && qm > qb, "resident bytes not ordered: {qd} / {qm} / {qb}");

    // mixed forward produces finite logits and serves over HTTP with
    // per-layer modes in /models
    let feat = 8 * 8 * 3;
    let mut rng = Pcg32::seeded(55);
    let x: Vec<f32> = (0..2 * feat).map(|_| rng.normal()).collect();
    let registry = Registry::new();
    registry.load_with_policy("mix", &dir, "rn", policy).unwrap();
    let server = Server::start(
        "127.0.0.1:0",
        registry,
        ServeConfig { workers: 1, intra_threads: 1, ..ServeConfig::default() },
    )
    .unwrap();
    let addr = server.local_addr();
    let body = Json::obj(vec![
        ("features", Json::arr(x[..feat].iter().map(|&v| Json::num(v)))),
    ])
    .to_string();
    let (status, resp) = http::client::request(addr, "POST", "/predict", Some(&body)).unwrap();
    assert_eq!(status, 200, "{resp}");
    let direct = mixed.predict(&x[..feat], 1).unwrap();
    assert_eq!(
        json::parse(&resp).unwrap().get("prediction").as_i64().unwrap() as i32,
        direct[0],
        "served mixed-mode prediction diverged from direct inference"
    );

    let (status, models) = http::client::request(addr, "GET", "/models", None).unwrap();
    assert_eq!(status, 200);
    let v = json::parse(&models).unwrap();
    let entry = &v.get("models").as_arr().unwrap()[0];
    assert_eq!(entry.get("compute_mode").as_str(), Some("mixed"));
    let listed = entry.get("layer_modes").as_arr().unwrap();
    assert_eq!(listed.len(), lm.len());
    for (j, l) in lm.iter().enumerate() {
        assert_eq!(listed[j].get("idx").as_usize(), Some(l.idx));
        assert_eq!(listed[j].get("mode").as_str(), Some(l.mode.label()));
        assert_eq!(listed[j].get("weights").as_usize(), Some(l.weights));
    }

    server.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// Acceptance: one registry hosts the same synthetic resnet bundle as a
/// DenseF32 entry and a BitPlane entry. Bit-plane `/predict` answers
/// must agree with dense top-1 on ≥ 99% of a procedural input set, the
/// HTTP path must match direct inference for both entries, and
/// `GET /models` must show ≥ 8× lower resident quantized bytes for the
/// bit-plane entry.
#[test]
fn bitplane_serving_agrees_with_dense_and_saves_memory() {
    let dir = bundle_dir("serve");
    export_synthetic_resnet_bundle(&dir, "rn", 33, "resnet8", 8, 10).unwrap();

    let registry = Registry::new();
    registry.load("dense", &dir, "rn").unwrap();
    registry
        .load_with_mode("bp", &dir, "rn", ComputeMode::BitPlane { act_planes: 24 })
        .unwrap();
    let dense_entry = registry.get("dense").unwrap();
    let bp_entry = registry.get("bp").unwrap();

    // ≥ 8× lower resident quantized weight bytes in bit-plane mode
    let dense_bytes = dense_entry.model.quantized_resident_bytes();
    let bp_bytes = bp_entry.model.quantized_resident_bytes();
    assert!(
        bp_bytes * 8 <= dense_bytes,
        "bit-plane resident {bp_bytes} B not ≥8× below dense {dense_bytes} B"
    );
    // FP residue is mode-independent
    assert_eq!(
        dense_entry.model.fp_resident_bytes(),
        bp_entry.model.fp_resident_bytes()
    );

    // top-1 agreement over a procedural input set (batched through the
    // exact models the server holds)
    const SAMPLES: usize = 100;
    let feat = 8 * 8 * 3;
    let mut rng = Pcg32::seeded(4242);
    let xs: Vec<f32> = (0..SAMPLES * feat).map(|_| rng.normal()).collect();
    let dense_preds = dense_entry.model.predict(&xs, SAMPLES).unwrap();
    let bp_preds = bp_entry.model.predict(&xs, SAMPLES).unwrap();
    let agree = dense_preds
        .iter()
        .zip(&bp_preds)
        .filter(|(a, b)| a == b)
        .count();
    assert!(
        agree * 100 >= SAMPLES * 99,
        "top-1 agreement {agree}/{SAMPLES} below 99%"
    );

    // the serving path answers /predict for both entries and matches the
    // direct predictions computed above
    let server = Server::start(
        "127.0.0.1:0",
        registry,
        ServeConfig { workers: 1, intra_threads: 1, ..ServeConfig::default() },
    )
    .unwrap();
    let addr = server.local_addr();
    for (name, preds) in [("dense", &dense_preds), ("bp", &bp_preds)] {
        for i in 0..4 {
            let body = Json::obj(vec![
                ("model", Json::str(name)),
                ("features",
                 Json::arr(xs[i * feat..(i + 1) * feat].iter().map(|&v| Json::num(v)))),
            ])
            .to_string();
            let (status, resp) =
                http::client::request(addr, "POST", "/predict", Some(&body)).unwrap();
            assert_eq!(status, 200, "{name} request {i}: {resp}");
            let pred = json::parse(&resp).unwrap().get("prediction").as_i64().unwrap();
            assert_eq!(pred as i32, preds[i], "{name} request {i} diverged");
        }
    }

    // GET /models reports both modes and the resident-bytes accounting
    let (status, body) = http::client::request(addr, "GET", "/models", None).unwrap();
    assert_eq!(status, 200);
    let v = json::parse(&body).unwrap();
    let models = v.get("models").as_arr().unwrap();
    assert_eq!(models.len(), 2);
    let find = |name: &str| {
        models
            .iter()
            .find(|m| m.get("name").as_str() == Some(name))
            .unwrap_or_else(|| panic!("missing {name} in /models"))
    };
    let dm = find("dense");
    let bm = find("bp");
    assert_eq!(dm.get("compute_mode").as_str(), Some("dense"));
    assert_eq!(bm.get("compute_mode").as_str(), Some("bitplane"));
    assert_eq!(dm.get("quantized_weight_bytes").as_usize(), Some(dense_bytes));
    assert_eq!(bm.get("quantized_weight_bytes").as_usize(), Some(bp_bytes));
    assert!(bm.get("resident_bytes").as_usize().unwrap() > 0);
    assert!(
        bm.get("fp_weight_bytes").as_usize().unwrap()
            == dm.get("fp_weight_bytes").as_usize().unwrap()
    );

    server.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// Satellite: the registry is no longer grow-only — unload releases an
/// entry, frees its slot for reloading, and `/models` accounting follows.
#[test]
fn registry_unload_and_reload() {
    let dir = bundle_dir("unload");
    let d_in = 12usize;
    export_synthetic_mlp_bundle(&dir, "m", 35, d_in, &[24, 16], 10).unwrap();

    let registry = Registry::new();
    registry.load("a", &dir, "m").unwrap();
    registry
        .load_with_mode("b", &dir, "m", ComputeMode::bit_plane())
        .unwrap();
    assert_eq!(registry.len(), 2);

    // an in-flight handle survives the unload
    let held = registry.get("a").unwrap();
    let gone = registry.unload("a").unwrap();
    assert_eq!(gone.name, "a");
    assert_eq!(registry.len(), 1);
    assert!(registry.get("a").is_none());
    assert!(registry.unload("a").is_err(), "double unload must fail");
    let probe = vec![0.5f32; d_in];
    assert_eq!(held.model.predict(&probe, 1).unwrap().len(), 1);
    drop(held);

    // the name is reusable, and the JSON listing follows the registry
    registry.load("a", &dir, "m").unwrap();
    assert_eq!(registry.len(), 2);
    let listed = registry.to_json();
    assert_eq!(listed.get("models").as_arr().unwrap().len(), 2);

    std::fs::remove_dir_all(&dir).ok();
}

/// The bit-plane engine is exact (not approximate) for ±1 inputs on a
/// dense layer chain: binarization of a ±1 row is a single plane with
/// β = 1, so mlp logits from both engines coincide to FP rounding.
#[test]
fn bitplane_mlp_exact_on_pm1_inputs_vs_dense() {
    let dir = bundle_dir("pm1");
    let d_in = 20usize;
    export_synthetic_mlp_bundle(&dir, "m", 36, d_in, &[32], 10).unwrap();
    let dense = InferenceModel::load(&dir, "m").unwrap();
    let bp = InferenceModel::load_with_mode(&dir, "m", ComputeMode::bit_plane()).unwrap();
    let mut rng = Pcg32::seeded(9);
    let x: Vec<f32> =
        (0..4 * d_in).map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 }).collect();
    // the single quantized layer sees ±1 rows (one plane, β = 1, zero
    // residual) and the head is FP in both modes, so the whole forward
    // differs only by FP summation order
    let a = dense.forward(&x, 4).unwrap();
    let b = bp.forward(&x, 4).unwrap();
    for (i, (p, q)) in a.iter().zip(&b).enumerate() {
        assert!(
            close(*p, *q, 1e-3),
            "logit {i}: dense {p} vs bitplane {q} on ±1 inputs"
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}
