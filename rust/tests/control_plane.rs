//! Control-plane integration tests (DESIGN.md §13): signed bundle repo
//! round trips, tamper/signature rejection over HTTP, drain-then-swap
//! under concurrent traffic, versioned delete, lazy admits, LRU
//! eviction, and the 405 + `Allow` method table — all against synthetic
//! encrypted bundles over real loopback sockets.

use std::net::SocketAddr;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use flexor::coordinator::export_synthetic_mlp_bundle;
use flexor::flexor::fxr::Container;
use flexor::inference::InferenceModel;
use flexor::repo::BundleRepo;
use flexor::serve::{http, ControlError, Registry, ServeConfig, Server};
use flexor::substrate::json::{self, Json};
use flexor::substrate::prng::Pcg32;

const D_IN: usize = 16;
const KEY: &[u8] = b"control-plane-test-key";

fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("flexor_ctl_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Export a seeded bundle under `root/src_<name>` and publish it as
/// `name@version`; returns the source dir (kept for reference loads).
fn publish_bundle(repo: &BundleRepo, root: &PathBuf, name: &str, version: &str, seed: u64) -> PathBuf {
    let src = root.join(format!("src_{name}_{version}"));
    export_synthetic_mlp_bundle(&src, name, seed, D_IN, &[32, 24], 10).unwrap();
    repo.publish(name, version, &src, name).unwrap();
    src
}

fn predict_body(model: &str, features: &[f32]) -> String {
    Json::obj(vec![
        ("model", Json::str(model)),
        ("features", Json::arr(features.iter().map(|&v| Json::num(v)))),
    ])
    .to_string()
}

fn inputs(n: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Pcg32::seeded(seed);
    (0..n).map(|_| (0..D_IN).map(|_| rng.normal()).collect()).collect()
}

fn post_json(addr: SocketAddr, method: &str, path: &str, body: &str) -> (u16, Json) {
    let (status, resp) = http::client::request(addr, method, path, Some(body)).unwrap();
    (status, json::parse(&resp).unwrap())
}

/// `GET /models` record for a full slot name, if present.
fn model_record(addr: SocketAddr, name: &str) -> Option<Json> {
    let (status, body) = http::client::request(addr, "GET", "/models", None).unwrap();
    assert_eq!(status, 200);
    let j = json::parse(&body).unwrap();
    let arr = j.get("models").as_arr().unwrap().to_vec();
    arr.into_iter().find(|m| m.get("name").as_str() == Some(name))
}

/// Publish (in both fxr container formats), verify, fetch, and load
/// through the repo — predictions must be bit-identical to loading the
/// source directory straight into a registry.
#[test]
fn repo_roundtrip_is_bit_identical_for_v1_and_v2_fxr() {
    let root = scratch("roundtrip");
    let repo = BundleRepo::init(&root.join("repo"), KEY).unwrap();

    // modern (v2) container
    let src_v2 = publish_bundle(&repo, &root, "m2", "v1", 41);
    // legacy (v1) container: rewrite the .fxr in place *before* publish,
    // so the repo hashes and serves the old format
    let src_v1 = root.join("src_legacy");
    export_synthetic_mlp_bundle(&src_v1, "m1", 42, D_IN, &[32, 24], 10).unwrap();
    let fxr_path = src_v1.join("m1.fxr");
    let container = Container::load(&fxr_path).unwrap();
    std::fs::write(&fxr_path, container.to_bytes_v1()).unwrap();
    repo.publish("m1", "v1", &src_v1, "m1").unwrap();

    let xs = inputs(8, 7);
    for (name, src) in [("m2", &src_v2), ("m1", &src_v1)] {
        let v = repo.verify(name, "v1").unwrap();
        assert_eq!(v.stem, name);

        // fetch to a fresh dir and load the copy
        let dest = root.join(format!("fetched_{name}"));
        repo.fetch(name, "v1", &dest).unwrap();
        let fetched = InferenceModel::load(&dest, name).unwrap();

        // admit through the registry control plane
        let mut registry = Registry::new();
        registry.set_repo(repo.clone());
        let report = registry.admit_from_repo(&format!("{name}@v1"), false).unwrap();
        assert_eq!(report.name, format!("{name}@v1"));
        assert_eq!(report.swapped_from, None);
        assert!(!report.lazy);
        let admitted = registry.resolve(name).unwrap().unwrap();
        assert_eq!(admitted.version, "v1");

        // straight load of the source dir — the baseline
        let direct_reg = Registry::new();
        let direct = direct_reg.load(name, src, name).unwrap();

        for x in &xs {
            let want = direct.model.predict(x, 1).unwrap();
            assert_eq!(fetched.predict(x, 1).unwrap(), want, "fetched {name} diverged");
            assert_eq!(admitted.model.predict(x, 1).unwrap(), want, "admitted {name} diverged");
        }
    }
}

/// One flipped byte in a stored bundle file must fail verification with
/// the bundle named, answer `409`/`bundle_rejected` over HTTP (echoing
/// the client's request id), and leave the registry untouched. A wrong
/// signing key is rejected the same way before any file is read.
#[test]
fn tampered_or_miskeyed_bundle_never_registers() {
    let root = scratch("tamper");
    let repo = BundleRepo::init(&root.join("repo"), KEY).unwrap();
    publish_bundle(&repo, &root, "good", "v1", 51);
    publish_bundle(&repo, &root, "bad", "v1", 52);

    // flip one byte of bad@v1's stored weights
    let stored = repo.bundle_dir("bad", "v1").join("bad.fxr");
    let mut bytes = std::fs::read(&stored).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0xFF;
    std::fs::write(&stored, bytes).unwrap();

    let err = repo.verify("bad", "v1").unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("bad@v1"), "error must name the bundle: {msg}");
    assert!(msg.contains("sha256 mismatch"), "{msg}");

    // wrong key: signature check fires before any file content is read
    let wrong = BundleRepo::open(repo.root(), b"not-the-key").unwrap();
    let err = wrong.verify("good", "v1").unwrap_err();
    assert!(format!("{err:#}").contains("signature mismatch"), "{err:#}");
    let mut miskeyed = Registry::new();
    miskeyed.set_repo(wrong);
    match miskeyed.admit_from_repo("good@v1", false) {
        Err(ControlError::Rejected(m)) => assert!(m.contains("signature mismatch"), "{m}"),
        other => panic!("expected Rejected, got {other:?}"),
    }
    assert!(miskeyed.is_empty(), "rejected admit must register nothing");

    // ...and over HTTP: 409, coded, request id echoed, registry unchanged
    let mut registry = Registry::new();
    registry.set_repo(repo.clone());
    registry.admit_from_repo("good@v1", false).unwrap();
    let server = Server::start("127.0.0.1:0", registry, ServeConfig::default()).unwrap();
    let addr = server.local_addr();

    let before = http::client::request(addr, "GET", "/models", None).unwrap().1;
    let (status, headers, body) = http::client::request_with_headers(
        addr,
        "POST",
        "/models",
        &[("X-Request-Id", "tamper-rid-7")],
        Some(r#"{"name":"bad@v1"}"#),
    )
    .unwrap();
    assert_eq!(status, 409, "{body}");
    let j = json::parse(&body).unwrap();
    assert_eq!(j.get("code").as_str(), Some("bundle_rejected"));
    assert_eq!(j.get("request_id").as_str(), Some("tamper-rid-7"));
    assert!(j.get("error").as_str().unwrap().contains("bad@v1"), "{body}");
    let echoed = headers.iter().find(|(k, _)| k == "x-request-id").map(|(_, v)| v.as_str());
    assert_eq!(echoed, Some("tamper-rid-7"), "request id must round-trip on the 409");

    let after = http::client::request(addr, "GET", "/models", None).unwrap().1;
    assert_eq!(before, after, "rejected bundle must leave the registry unchanged");
    assert!(model_record(addr, "bad@v1").is_none());

    server.shutdown();
    std::fs::remove_dir_all(&root).ok();
}

/// Hot-swap `resnet20@v1 → @v2` while concurrent `/predict` traffic is
/// in flight: every healthy request answers 2xx throughout, in-flight
/// requests drain on the old version, and admissions after the swap
/// serve the new one.
#[test]
fn hot_swap_under_concurrent_traffic_drains_cleanly() {
    let root = scratch("swap");
    let repo = BundleRepo::init(&root.join("repo"), KEY).unwrap();
    publish_bundle(&repo, &root, "resnet20", "v1", 61);
    publish_bundle(&repo, &root, "resnet20", "v2", 62);

    let mut registry = Registry::new();
    registry.set_repo(repo);
    registry.admit_from_repo("resnet20@v1", false).unwrap();
    let cfg = ServeConfig { workers: 2, queue_capacity: 1024, ..ServeConfig::default() };
    let server = Server::start("127.0.0.1:0", registry, cfg).unwrap();
    let addr = server.local_addr();

    let x0 = inputs(1, 3).remove(0);
    let (status, v) = post_json(addr, "POST", "/predict", &predict_body("resnet20", &x0));
    assert_eq!(status, 200, "{v}");
    assert_eq!(v.get("model").as_str(), Some("resnet20@v1"));

    const CLIENTS: usize = 4;
    let stop = Arc::new(AtomicBool::new(false));
    let handles: Vec<_> = (0..CLIENTS)
        .map(|c| {
            let stop = stop.clone();
            thread::spawn(move || -> Vec<(u16, String)> {
                let xs = inputs(8, 100 + c as u64);
                let mut seen = Vec::new();
                let mut i = 0usize;
                while !stop.load(Ordering::Relaxed) {
                    let body = predict_body("resnet20", &xs[i % xs.len()]);
                    let (status, v) = post_json(addr, "POST", "/predict", &body);
                    let model = v.get("model").as_str().unwrap_or("").to_string();
                    seen.push((status, model));
                    i += 1;
                }
                seen
            })
        })
        .collect();

    thread::sleep(Duration::from_millis(100));
    let (status, report) = post_json(addr, "POST", "/models", r#"{"name":"resnet20@v2"}"#);
    assert_eq!(status, 200, "{report}");
    assert_eq!(report.get("name").as_str(), Some("resnet20@v2"));
    assert_eq!(report.get("swapped_from").as_str(), Some("resnet20@v1"));
    assert!(!report.get("lazy").as_bool().unwrap());

    // an admission after the swap must serve v2
    let (status, v) = post_json(addr, "POST", "/predict", &predict_body("resnet20", &x0));
    assert_eq!(status, 200, "{v}");
    assert_eq!(v.get("model").as_str(), Some("resnet20@v2"));

    thread::sleep(Duration::from_millis(100));
    stop.store(true, Ordering::Relaxed);
    let mut total = 0usize;
    let mut versions = std::collections::BTreeSet::new();
    for h in handles {
        for (status, model) in h.join().unwrap() {
            assert_eq!(status, 200, "a healthy request failed during the swap ({model})");
            assert!(
                model == "resnet20@v1" || model == "resnet20@v2",
                "unexpected serving version {model}"
            );
            versions.insert(model);
            total += 1;
        }
    }
    assert!(total > 0, "no concurrent traffic was generated");
    assert!(versions.contains("resnet20@v1"), "no request landed before the swap");

    // the swap is visible in the listing and the counters
    let (_, listing) = http::client::request(addr, "GET", "/models", None).unwrap();
    let j = json::parse(&listing).unwrap();
    assert_eq!(j.get("swaps_total").as_usize(), Some(1));
    let v2 = model_record(addr, "resnet20@v2").unwrap();
    assert_eq!(v2.get("serving").as_bool(), Some(true));
    let v1 = model_record(addr, "resnet20@v1").unwrap();
    assert_eq!(v1.get("serving").as_bool(), Some(false));
    let (_, prom) =
        http::client::request(addr, "GET", "/metrics?format=prometheus", None).unwrap();
    assert!(prom.contains("flexor_model_swaps_total 1"), "{prom}");
    assert!(prom.contains("flexor_model_evictions_total 0"), "{prom}");

    server.shutdown();
    std::fs::remove_dir_all(&root).ok();
}

/// Wrong-method requests on known paths answer `405` with an `Allow`
/// header instead of `404`/`no_route`; unknown paths still 404. Runs on
/// a repo-backed empty registry — the control plane makes that a legal
/// server configuration.
#[test]
fn known_paths_answer_405_with_allow_header() {
    let root = scratch("methods");
    let repo = BundleRepo::init(&root.join("repo"), KEY).unwrap();
    let mut registry = Registry::new();
    registry.set_repo(repo);
    let server = Server::start("127.0.0.1:0", registry, ServeConfig::default()).unwrap();
    let addr = server.local_addr();

    for (method, path, allow) in [
        ("GET", "/predict", "POST"),
        ("DELETE", "/models", "GET, POST"),
        ("PUT", "/models", "GET, POST"),
        ("POST", "/metrics", "GET"),
        ("POST", "/healthz", "GET"),
        ("DELETE", "/readyz", "GET"),
        ("POST", "/models/x/profile", "GET"),
        ("PUT", "/models/x", "DELETE"),
    ] {
        let (status, headers, body) =
            http::client::request_with_headers(addr, method, path, &[], None).unwrap();
        assert_eq!(status, 405, "{method} {path}: {body}");
        let j = json::parse(&body).unwrap();
        assert_eq!(j.get("code").as_str(), Some("method_not_allowed"), "{method} {path}");
        assert!(!j.get("request_id").is_null(), "{method} {path}");
        let got = headers.iter().find(|(k, _)| k == "allow").map(|(_, v)| v.as_str());
        assert_eq!(got, Some(allow), "{method} {path}");
    }

    // unknown paths are still 404/no_route, with no Allow header
    let (status, headers, body) =
        http::client::request_with_headers(addr, "GET", "/nope", &[], None).unwrap();
    assert_eq!(status, 404);
    assert_eq!(json::parse(&body).unwrap().get("code").as_str(), Some("no_route"));
    assert!(headers.iter().all(|(k, _)| k != "allow"));

    server.shutdown();
    std::fs::remove_dir_all(&root).ok();
}

/// Lazy admits register without loading; the first `/predict` resolves
/// (loads) the bundle. `DELETE` drops one version (repointing the bare
/// alias) or the whole alias, and unknown names answer 404.
#[test]
fn lazy_admit_and_versioned_delete() {
    let root = scratch("lazy");
    let repo = BundleRepo::init(&root.join("repo"), KEY).unwrap();
    publish_bundle(&repo, &root, "a", "v1", 71);
    publish_bundle(&repo, &root, "a", "v2", 72);

    let mut registry = Registry::new();
    registry.set_repo(repo);
    let server = Server::start("127.0.0.1:0", registry, ServeConfig::default()).unwrap();
    let addr = server.local_addr();

    let (status, report) = post_json(addr, "POST", "/models", r#"{"name":"a@v1","lazy":true}"#);
    assert_eq!(status, 200, "{report}");
    assert!(report.get("lazy").as_bool().unwrap());
    let rec = model_record(addr, "a@v1").unwrap();
    assert_eq!(rec.get("resident").as_bool(), Some(false), "lazy admit must not load");
    assert_eq!(rec.get("serving").as_bool(), Some(true));

    // first predict forces the load
    let x0 = inputs(1, 5).remove(0);
    let (status, v) = post_json(addr, "POST", "/predict", &predict_body("a", &x0));
    assert_eq!(status, 200, "{v}");
    assert_eq!(v.get("model").as_str(), Some("a@v1"));
    let rec = model_record(addr, "a@v1").unwrap();
    assert_eq!(rec.get("resident").as_bool(), Some(true));

    // second version, then delete it: the bare alias repoints back to v1
    let (status, _) = post_json(addr, "POST", "/models", r#"{"name":"a@v2"}"#);
    assert_eq!(status, 200);
    let (status, v) = post_json(addr, "POST", "/predict", &predict_body("a", &x0));
    assert_eq!(status, 200, "{v}");
    assert_eq!(v.get("model").as_str(), Some("a@v2"));
    let (status, del) = post_json(addr, "DELETE", "/models/a@v2", "");
    assert_eq!(status, 200, "{del}");
    assert_eq!(del.get("removed_versions").as_usize(), Some(1));
    assert!(model_record(addr, "a@v2").is_none());
    let (status, v) = post_json(addr, "POST", "/predict", &predict_body("a", &x0));
    assert_eq!(status, 200, "{v}");
    assert_eq!(v.get("model").as_str(), Some("a@v1"));

    // drop the whole alias; predicts now 404
    let (status, del) = post_json(addr, "DELETE", "/models/a", "");
    assert_eq!(status, 200, "{del}");
    assert_eq!(del.get("removed_versions").as_usize(), Some(1));
    let (status, v) = post_json(addr, "POST", "/predict", &predict_body("a", &x0));
    assert_eq!(status, 404, "{v}");
    assert_eq!(v.get("code").as_str(), Some("unknown_model"));
    let (status, v) = post_json(addr, "DELETE", "/models/a", "");
    assert_eq!(status, 404, "{v}");
    assert_eq!(v.get("code").as_str(), Some("unknown_model"));

    server.shutdown();
    std::fs::remove_dir_all(&root).ok();
}

/// With a resident-bytes budget that fits one model, admitting a second
/// evicts the least-recently-used one; the evicted slot stays registered
/// and reloads bit-identically on the next resolve, still under budget.
#[test]
fn lru_eviction_keeps_budget_and_reloads_bit_identically() {
    let root = scratch("evict");
    let repo = BundleRepo::init(&root.join("repo"), KEY).unwrap();
    let src_a = publish_bundle(&repo, &root, "a", "v1", 81);
    publish_bundle(&repo, &root, "b", "v1", 82);

    let mut registry = Registry::new();
    registry.set_repo(repo);
    registry.admit_from_repo("a@v1", false).unwrap();
    let one = registry.resident_bytes_total();
    assert!(one > 0);
    // budget fits one resident model but not two (same geometry → same size)
    let budget = one + one / 2;
    registry.set_resident_budget(Some(budget));

    let xs = inputs(6, 9);
    let reference = InferenceModel::load(&src_a, "a").unwrap();
    let expected: Vec<Vec<i32>> =
        xs.iter().map(|x| reference.predict(x, 1).unwrap()).collect();

    registry.admit_from_repo("b@v1", false).unwrap();
    assert_eq!(registry.evictions_total(), 1, "admitting b must evict a");
    assert!(
        registry.resident_bytes_total() <= budget,
        "resident {} exceeds budget {budget}",
        registry.resident_bytes_total()
    );
    assert!(registry.get("a@v1").is_none(), "a must be non-resident");
    assert!(registry.names().contains(&"a@v1".to_string()), "a must stay registered");

    // resolving a re-verifies + reloads it (evicting b in turn) and the
    // answers are bit-identical to the pre-eviction reference
    let back = registry.resolve("a").unwrap().expect("evicted slot must lazily reload");
    for (x, want) in xs.iter().zip(&expected) {
        assert_eq!(&back.model.predict(x, 1).unwrap(), want, "reloaded model diverged");
    }
    assert_eq!(registry.evictions_total(), 2, "reloading a must evict b");
    assert!(registry.resident_bytes_total() <= budget);
    assert!(registry.get("b@v1").is_none());
    assert_eq!(registry.len(), 2, "eviction must never unregister slots");

    std::fs::remove_dir_all(&root).ok();
}
