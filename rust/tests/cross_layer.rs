//! Cross-layer consistency: the Rust flexor core (matrix / decrypt / fxr)
//! against the Python-emitted artifact metadata — the two sides must agree
//! on M⊕, storage accounting and decrypt semantics or deployed models
//! would silently decode garbage.

use std::path::Path;

use flexor::flexor::{bits_per_weight, num_slices};
use flexor::runtime::{initbin, Manifest};

fn manifest() -> Option<Manifest> {
    let p = Path::new("artifacts");
    if !p.join("manifest.json").exists() {
        eprintln!("SKIP: run `make artifacts` first");
        return None;
    }
    Some(Manifest::load(p).unwrap())
}

#[test]
fn meta_mxor_parses_and_matches_spec() {
    let Some(man) = manifest() else { return };
    let meta = man.config("quickstart_mlp").unwrap();
    let spec = meta.flexor_default.as_ref().expect("flexor spec");
    assert_eq!(spec.q, 1);
    assert_eq!(spec.n_in, 8);
    assert_eq!(spec.n_out, 10);
    assert_eq!(spec.mxor.len(), 1);
    let m = &spec.mxor[0];
    assert_eq!(m.n_out(), 10);
    assert_eq!(m.n_in(), 8);
    // config used n_tap=2
    for r in 0..m.n_out() {
        assert_eq!(m.n_tap(r), 2, "row {r}");
    }
    assert!((spec.bits_per_weight - bits_per_weight(1, 8, 10)).abs() < 1e-12);
}

#[test]
fn meta_storage_rows_match_rust_accounting() {
    let Some(man) = manifest() else { return };
    let meta = man.config("quickstart_mlp").unwrap();
    let spec = meta.flexor_default.as_ref().unwrap();
    for layer in &meta.storage_layers {
        let n: usize = layer.shape.iter().product();
        assert_eq!(n, layer.weights);
        let expect = spec.q * num_slices(n, spec.n_out) * spec.n_in;
        assert_eq!(layer.stored_bits, expect, "layer {}", layer.idx);
    }
}

#[test]
fn init_bin_w_enc_shape_matches_slices() {
    let Some(man) = manifest() else { return };
    let meta = man.config("quickstart_mlp").unwrap();
    let leaves = initbin::load_init_bin(&meta.init_bin_path()).unwrap();
    let spec = meta.flexor_default.as_ref().unwrap();
    for (layer_idx, (enc_leaf, alpha_leaf)) in meta.quantized_param_leaves() {
        let enc = &leaves[enc_leaf];
        let storage = meta
            .storage_layers
            .iter()
            .find(|l| l.idx == layer_idx)
            .unwrap();
        assert_eq!(
            enc.shape,
            vec![spec.q, num_slices(storage.weights, spec.n_out), spec.n_in],
            "layer {layer_idx} w_enc"
        );
        let alpha = &leaves[alpha_leaf];
        assert_eq!(alpha.shape, vec![spec.q, *storage.shape.last().unwrap()]);
        // encrypted weights init ~ N(0, 0.001²) (paper §3): tiny but nonzero
        let vals = enc.as_f32().unwrap();
        let maxabs = vals.iter().fold(0f32, |m, v| m.max(v.abs()));
        assert!(maxabs > 0.0 && maxabs < 0.01, "w_enc init scale {maxabs}");
    }
}

#[test]
fn rust_decrypt_agrees_with_artifact_convention() {
    // Decrypt init-state encrypted weights with the Rust engine and verify
    // every output is ±1 with a roughly balanced bit distribution (the
    // design goal of §2's Hamming-distance argument) — plus exact
    // agreement between the word-parallel and scalar engines on real data.
    let Some(man) = manifest() else { return };
    let meta = man.config("quickstart_mlp").unwrap();
    let leaves = initbin::load_init_bin(&meta.init_bin_path()).unwrap();
    let spec = meta.flexor_default.as_ref().unwrap();
    for (layer_idx, (enc_leaf, _)) in meta.quantized_param_leaves() {
        let enc = leaves[enc_leaf].as_f32().unwrap();
        let storage = meta
            .storage_layers
            .iter()
            .find(|l| l.idx == layer_idx)
            .unwrap();
        let packed =
            flexor::flexor::decrypt::pack_encrypted(&enc, spec.n_in).unwrap();
        let d = flexor::flexor::Decryptor::new(spec.mxor[0].clone());
        let fast = d.decrypt_columns(&packed).unwrap();
        let slow = d.decrypt_scalar(&packed).unwrap();
        assert_eq!(fast, slow, "engines disagree on layer {layer_idx}");
        let signs = d.decrypt_to_signs(&packed, storage.weights).unwrap();
        let pos = signs.iter().filter(|&&s| s > 0.0).count();
        let frac = pos as f64 / signs.len() as f64;
        assert!(
            (0.30..=0.70).contains(&frac),
            "layer {layer_idx}: decrypted bit balance {frac}"
        );
    }
}
