//! Chaos harness (DESIGN.md §12): drive the serving stack over real
//! loopback sockets while `substrate::fault` injects each fault class,
//! and assert the contract that matters — **the server keeps answering,
//! and healthy traffic stays bit-identical to an unfaulted run**.
//!
//! Fault state is process-global, so every test serializes on one
//! poison-safe mutex and disarms via a drop guard; baselines are always
//! captured before arming.

use std::net::SocketAddr;
use std::path::PathBuf;
use std::sync::{Mutex, MutexGuard};
use std::thread;
use std::time::{Duration, Instant};

use flexor::coordinator::export_synthetic_mlp_bundle;
use flexor::inference::ComputeMode;
use flexor::serve::{http, Registry, ServeConfig, Server};
use flexor::substrate::fault::{self, FaultPlan};
use flexor::substrate::json::{self, Json};

const D_IN: usize = 16;

/// All chaos tests hold this while armed; poison-safe so one failing
/// test does not cascade into every other test's lock().unwrap().
fn chaos_lock() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Disarms on every exit path, panicking assertions included.
struct Disarm;
impl Drop for Disarm {
    fn drop(&mut self) {
        fault::disarm();
    }
}

fn arm(plan: FaultPlan) -> Disarm {
    fault::arm(plan);
    Disarm
}

fn bundle_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("flexor_chaos_{tag}_{}", std::process::id()))
}

fn start_server(tag: &str, cfg: ServeConfig, mode: Option<ComputeMode>) -> (Server, PathBuf) {
    let dir = bundle_dir(tag);
    export_synthetic_mlp_bundle(&dir, "served", 7, D_IN, &[32, 24], 10).unwrap();
    let registry = match mode {
        Some(m) => Registry::with_default_mode(m),
        None => Registry::new(),
    };
    registry.load("served", &dir, "served").unwrap();
    let server = Server::start("127.0.0.1:0", registry, cfg).unwrap();
    (server, dir)
}

fn predict_body(features: &[f32]) -> String {
    Json::obj(vec![
        ("model", Json::str("served")),
        ("features", Json::arr(features.iter().map(|&v| Json::num(v)))),
    ])
    .to_string()
}

fn post_predict(addr: SocketAddr, body: &str) -> (u16, Json) {
    let (status, resp) = http::client::request(addr, "POST", "/predict", Some(body)).unwrap();
    (status, json::parse(&resp).unwrap())
}

/// Deterministic probe inputs + their served classes (the baseline the
/// faulted runs must reproduce bit-identically).
fn baseline(addr: SocketAddr) -> Vec<(Vec<f32>, i64)> {
    (0..4u32)
        .map(|i| {
            let x: Vec<f32> =
                (0..D_IN).map(|j| ((i as f32 + 1.0) * 0.3 + j as f32 * 0.17).sin()).collect();
            let (status, v) = post_predict(addr, &predict_body(&x));
            assert_eq!(status, 200, "baseline request failed: {v}");
            (x, v.get("prediction").as_i64().unwrap())
        })
        .collect()
}

fn assert_matches_baseline(addr: SocketAddr, base: &[(Vec<f32>, i64)], ctx: &str) {
    for (i, (x, want)) in base.iter().enumerate() {
        let (status, v) = post_predict(addr, &predict_body(x));
        assert_eq!(status, 200, "{ctx}: probe {i} failed: {v}");
        assert_eq!(
            v.get("prediction").as_i64(),
            Some(*want),
            "{ctx}: probe {i} diverged from the unfaulted baseline: {v}"
        );
    }
}

fn metrics_json(addr: SocketAddr) -> Json {
    let (status, m) = http::client::request(addr, "GET", "/metrics", None).unwrap();
    assert_eq!(status, 200);
    json::parse(&m).unwrap()
}

/// `panic_shard:1.0`: every batch forward panics. Each faulted request
/// gets a coded `500 worker_panic` (no hangs, no dropped channels), the
/// worker that panics [`MAX_CONSECUTIVE_PANICS`] times in a row is
/// respawned by the supervisor, and after disarming the same server
/// serves the baseline bit-identically.
#[test]
fn panic_storm_is_contained_and_workers_respawn() {
    let _l = chaos_lock();
    let cfg = ServeConfig { workers: 1, ..ServeConfig::default() };
    let (server, dir) = start_server("panic", cfg, None);
    let addr = server.local_addr();
    let base = baseline(addr);

    {
        let _g = arm(FaultPlan { panic_shard_p: 1.0, ..FaultPlan::default() });
        for i in 0..5 {
            let (status, v) = post_predict(addr, &predict_body(&base[0].0));
            assert_eq!(status, 500, "faulted request {i}: {v}");
            assert_eq!(v.get("code").as_str(), Some("worker_panic"), "{v}");
            assert!(
                v.get("error").as_str().unwrap_or("").contains("injected fault"),
                "{v}"
            );
        }
    } // disarmed here

    // the panic storm killed ≥ one worker; wait for the supervisor to
    // bring readiness back before probing
    let t0 = Instant::now();
    loop {
        let (status, _) = http::client::request(addr, "GET", "/readyz", None).unwrap();
        if status == 200 {
            break;
        }
        assert!(t0.elapsed() < Duration::from_secs(5), "server never became ready again");
        thread::sleep(Duration::from_millis(20));
    }
    assert_matches_baseline(addr, &base, "after panic storm");

    let m = metrics_json(addr);
    assert!(m.get("worker_panics_total").as_usize().unwrap() >= 5, "{m}");
    assert!(m.get("worker_restarts_total").as_usize().unwrap() >= 1, "{m}");

    // the fault counters are on the Prometheus exposition too
    let (status, prom) =
        http::client::request(addr, "GET", "/metrics?format=prometheus", None).unwrap();
    assert_eq!(status, 200);
    for name in [
        "flexor_worker_panics_total",
        "flexor_worker_restarts_total",
        "flexor_shed_total",
        "flexor_deadline_expired_total",
    ] {
        assert!(prom.contains(name), "prometheus exposition missing {name}");
    }

    server.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// `slow_layer`: forwards get slower but stay correct — bit-identical
/// to the baseline while the fault fires.
#[test]
fn slow_layers_do_not_change_answers() {
    let _l = chaos_lock();
    let (server, dir) = start_server("slow", ServeConfig::default(), None);
    let addr = server.local_addr();
    let base = baseline(addr);

    let _g = arm(FaultPlan { slow_layer_ms: 25, ..FaultPlan::default() });
    assert_matches_baseline(addr, &base, "under slow_layer");

    server.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// `queue_stall` races deadlines: a request with a short `X-Deadline-Ms`
/// is shed with a coded `503 deadline_exceeded` + `Retry-After` once the
/// stall outlives it, while deadline-less traffic through the same stall
/// still serves the baseline answer.
#[test]
fn queue_stall_sheds_deadlined_requests_only() {
    let _l = chaos_lock();
    let cfg = ServeConfig { workers: 1, ..ServeConfig::default() };
    let (server, dir) = start_server("stall", cfg, None);
    let addr = server.local_addr();
    let base = baseline(addr);

    let _g = arm(FaultPlan { queue_stall_ms: 120, ..FaultPlan::default() });
    let (status, headers, resp) = http::client::request_with_headers(
        addr,
        "POST",
        "/predict",
        &[("X-Deadline-Ms", "20")],
        Some(&predict_body(&base[0].0)),
    )
    .unwrap();
    assert_eq!(status, 503, "{resp}");
    let v = json::parse(&resp).unwrap();
    assert_eq!(v.get("code").as_str(), Some("deadline_exceeded"), "{v}");
    assert!(
        headers.iter().any(|(k, _)| k == "retry-after"),
        "shed response missing Retry-After: {headers:?}"
    );

    // no deadline → the stall is just latency
    assert_matches_baseline(addr, &base, "under queue_stall without deadline");

    let m = metrics_json(addr);
    assert!(m.get("deadline_expired_total").as_usize().unwrap() >= 1, "{m}");

    server.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// `flip_word:1.0` on the Encrypted engine: the integrity re-hash sees a
/// corrupted panel word, the forward panics into the worker's
/// `catch_unwind`, and the client gets a coded `500 integrity` — never a
/// silently wrong prediction. Disarmed, the same server serves the same
/// bits as before.
#[test]
fn flipped_words_surface_as_integrity_errors_not_wrong_answers() {
    let _l = chaos_lock();
    let cfg = ServeConfig { workers: 1, ..ServeConfig::default() };
    let (server, dir) = start_server("flip", cfg, Some(ComputeMode::encrypted()));
    let addr = server.local_addr();
    let base = baseline(addr);

    {
        let _g = arm(FaultPlan { flip_word_p: 1.0, ..FaultPlan::default() });
        let (status, v) = post_predict(addr, &predict_body(&base[0].0));
        assert_eq!(status, 500, "{v}");
        assert_eq!(v.get("code").as_str(), Some("integrity"), "{v}");
        assert!(v.get("error").as_str().unwrap_or("").contains("integrity"), "{v}");
    }

    // stored panels were never mutated — recovery is immediate
    let t0 = Instant::now();
    loop {
        let (status, _) = http::client::request(addr, "GET", "/readyz", None).unwrap();
        if status == 200 {
            break;
        }
        assert!(t0.elapsed() < Duration::from_secs(5), "server never became ready again");
        thread::sleep(Duration::from_millis(20));
    }
    assert_matches_baseline(addr, &base, "after flip_word disarm");

    let m = metrics_json(addr);
    assert!(m.get("worker_panics_total").as_usize().unwrap() >= 1, "{m}");

    server.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// A bundle corrupted on disk is rejected at load with a structured
/// integrity error naming the damaged section — it never reaches the
/// registry, so it can never be served.
#[test]
fn corrupted_bundle_is_rejected_at_load() {
    let _l = chaos_lock();
    let dir = bundle_dir("corrupt");
    export_synthetic_mlp_bundle(&dir, "served", 7, D_IN, &[32, 24], 10).unwrap();
    let path = dir.join("served.fxr");
    let mut bytes = std::fs::read(&path).unwrap();
    // flip a byte inside layer[0]'s body: past the 20-byte header, the
    // meta json, and the layer's own 8-byte len+crc prefix — so the
    // failure is deterministically a section-checksum mismatch, not a
    // parse error on a damaged length field
    let meta_len = u32::from_le_bytes(bytes[12..16].try_into().unwrap()) as usize;
    let target = 20 + meta_len + 8 + 4;
    bytes[target] ^= 0xFF;
    std::fs::write(&path, &bytes).unwrap();

    let registry = Registry::new();
    let err = registry.load("served", &dir, "served").unwrap_err();
    let chain = format!("{err:#}");
    assert!(chain.contains("integrity"), "error does not name corruption: {chain}");
    assert!(chain.contains("crc32"), "error does not name the checksum: {chain}");
    assert!(chain.contains("served"), "error does not name the model: {chain}");
    assert!(registry.is_empty(), "corrupt bundle must not register");

    // and a server cannot start on the (empty) registry
    assert!(Server::start("127.0.0.1:0", registry, ServeConfig::default()).is_err());
    std::fs::remove_dir_all(&dir).ok();
}
