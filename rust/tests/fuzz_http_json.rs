//! Deterministic structure-aware fuzzing of the serving front-end's two
//! incremental parsers (DESIGN.md §14):
//!
//! * [`FrameParser`] — the resumable HTTP/1.1 request framer the event
//!   loop feeds from nonblocking sockets. Fuzzed with generated requests
//!   run through structural mutations (truncation, byte flips, header
//!   splicing, pipelined duplication) and delivered at randomized chunk
//!   boundaries. Invariant: never panics, never yields a frame violating
//!   its own bounds, and every rejection carries a coded 4xx status.
//! * The streaming JSON [`Lexer`] — differentially fuzzed against the
//!   recursive tree parser (`json::parse`), which the thread-per-
//!   connection front-end still uses and which therefore serves as the
//!   behavioral oracle: both must agree accept/reject on every input,
//!   and on acceptance the rebuilt tree must be identical. The
//!   [`PredictVisitor`] extraction is checked against the tree-based
//!   field extraction `handle_predict` performs.
//!
//! Everything is seeded: `FLEXOR_FUZZ_SEED` picks the master seed
//! (CI passes a time-derived one), `FLEXOR_FUZZ_CASES` the case count
//! (default 10_000 — the tier-1 budget). Each case derives its own
//! splitmix64 stream from the master seed, and a failing case prints
//! `seed=…` plus the exact input bytes so any failure replays with
//! `FLEXOR_FUZZ_SEED=<seed> cargo test --test fuzz_http_json`.

use std::panic::{self, AssertUnwindSafe};

use flexor::serve::http::{FrameParser, PredictVisitor, MAX_MODEL_NAME};
use flexor::substrate::json::{self, lex_to_tree, Json, Lexer};

// ---------------------------------------------------------------------------
// splitmix64: tiny, seedable, and stable across platforms — the per-case
// stream is fully determined by (master seed, case index).
// ---------------------------------------------------------------------------

struct Rng(u64);

impl Rng {
    fn new(seed: u64) -> Rng {
        Rng(seed)
    }

    fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }

    /// Uniform in `0..n` (n > 0).
    fn below(&mut self, n: usize) -> usize {
        (self.next() % n as u64) as usize
    }

    /// True with probability `percent`/100.
    fn chance(&mut self, percent: u64) -> bool {
        self.next() % 100 < percent
    }

    fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

fn master_seed() -> u64 {
    match std::env::var("FLEXOR_FUZZ_SEED") {
        Ok(s) => {
            let t = s.trim();
            let parsed = match t.strip_prefix("0x").or_else(|| t.strip_prefix("0X")) {
                Some(hex) => u64::from_str_radix(hex, 16),
                None => t.parse(),
            };
            parsed.unwrap_or_else(|_| panic!("unparseable FLEXOR_FUZZ_SEED {s:?}"))
        }
        Err(_) => 0x5eed_f1e0_2020_0001,
    }
}

fn case_count() -> usize {
    match std::env::var("FLEXOR_FUZZ_CASES") {
        Ok(s) => s.trim().parse().unwrap_or_else(|_| panic!("unparseable FLEXOR_FUZZ_CASES {s:?}")),
        Err(_) => 10_000,
    }
}

/// Derive the per-case seed. Mixing through splitmix keeps neighboring
/// cases decorrelated even for sequential master seeds.
fn case_seed(master: u64, case: usize) -> u64 {
    Rng::new(master ^ (case as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15)).next()
}

/// Printable escape of fuzz input for failure reports.
fn escape(bytes: &[u8]) -> String {
    let mut s = String::with_capacity(bytes.len() + 16);
    for &b in bytes.iter().take(2048) {
        match b {
            b'\\' => s.push_str("\\\\"),
            b'\n' => s.push_str("\\n"),
            b'\r' => s.push_str("\\r"),
            b'\t' => s.push_str("\\t"),
            0x20..=0x7e => s.push(b as char),
            _ => s.push_str(&format!("\\x{b:02x}")),
        }
    }
    if bytes.len() > 2048 {
        s.push_str(&format!("… ({} bytes total)", bytes.len()));
    }
    s
}

/// Run one fuzz case with panic containment: any panic (assertion or
/// parser bug) is re-raised with the seed and input attached so the case
/// replays deterministically.
fn run_case(master: u64, case: usize, input: &[u8], f: impl FnOnce()) {
    let seed = case_seed(master, case);
    if let Err(e) = panic::catch_unwind(AssertUnwindSafe(f)) {
        let msg = e
            .downcast_ref::<String>()
            .map(|s| s.as_str())
            .or_else(|| e.downcast_ref::<&str>().copied())
            .unwrap_or("non-string panic payload");
        panic!(
            "fuzz case failed: seed=0x{master:x} case={case} case_seed=0x{seed:x}\n\
             input: {}\npanic: {msg}",
            escape(input)
        );
    }
}

// ---------------------------------------------------------------------------
// JSON document generator
// ---------------------------------------------------------------------------

const NUM_POOL: &[&str] = &[
    "0",
    "-0",
    "1",
    "-1",
    "42",
    "3.25",
    "-3e-2",
    "0.1",
    "1E+2",
    "1e308",
    "-1e-308",
    "5e-324",
    "2.2250738585072014e-308",
    "1.7976931348623157e308",
    "123456789012345678",
    "9007199254740993",
    "1e999",
    "0.000001",
];

fn gen_number(rng: &mut Rng, out: &mut String) {
    if rng.chance(70) {
        out.push_str(rng.pick(NUM_POOL));
    } else {
        let a = rng.next() % 1_000_000;
        let b = rng.next() % 1000;
        let e = (rng.next() % 40) as i64 - 20;
        out.push_str(&format!("{}{a}.{b}e{e}", if rng.chance(30) { "-" } else { "" }));
    }
}

const STR_PIECES: &[&str] = &[
    "a", "model", "features", "serve", "é", "🦀", " ", "_", "-", "0", "\\\"", "\\\\", "\\n",
    "\\t", "\\u0041", "\\ud83d\\ude00", "\\u00e9", "\\/",
];

fn gen_string(rng: &mut Rng, out: &mut String) {
    out.push('"');
    for _ in 0..rng.below(6) {
        out.push_str(rng.pick(STR_PIECES));
    }
    out.push('"');
}

fn gen_value(rng: &mut Rng, depth: usize, out: &mut String) {
    let choice = if depth >= 5 { rng.below(4) } else { rng.below(6) };
    match choice {
        0 => out.push_str("null"),
        1 => out.push_str(if rng.chance(50) { "true" } else { "false" }),
        2 => gen_number(rng, out),
        3 => gen_string(rng, out),
        4 => {
            out.push('[');
            let n = rng.below(5);
            for i in 0..n {
                if i > 0 {
                    out.push(',');
                }
                gen_value(rng, depth + 1, out);
            }
            out.push(']');
        }
        _ => {
            out.push('{');
            let n = rng.below(4);
            for i in 0..n {
                if i > 0 {
                    out.push(',');
                }
                gen_string(rng, out);
                out.push(':');
                gen_value(rng, depth + 1, out);
            }
            out.push('}');
        }
    }
}

/// A predict-shaped document: the hot-path schema plus adversarial
/// variations (wrong-typed fields, oversized names, duplicate keys,
/// extra nested keys the visitor must skip).
fn gen_predict_doc(rng: &mut Rng, out: &mut String) {
    out.push('{');
    let mut first = true;
    let mut sep = |out: &mut String, first: &mut bool| {
        if !*first {
            out.push(',');
        }
        *first = false;
    };
    if rng.chance(85) {
        sep(out, &mut first);
        out.push_str("\"model\":");
        match rng.below(6) {
            0 => out.push_str("null"),
            1 => gen_number(rng, out),
            2 => out.push_str(&format!("\"{}\"", "m".repeat(MAX_MODEL_NAME + 1 + rng.below(8)))),
            _ => gen_string(rng, out),
        }
    }
    if rng.chance(90) {
        sep(out, &mut first);
        out.push_str("\"features\":");
        match rng.below(8) {
            0 => out.push_str("null"),
            1 => gen_string(rng, out),
            2 => out.push_str("{\"nested\":1}"),
            3 => out.push_str("[1,null,2]"),
            4 => out.push_str("[[1],2]"),
            _ => {
                out.push('[');
                let n = rng.below(10);
                for i in 0..n {
                    if i > 0 {
                        out.push(',');
                    }
                    gen_number(rng, out);
                }
                out.push(']');
            }
        }
    }
    for _ in 0..rng.below(3) {
        sep(out, &mut first);
        gen_string(rng, out);
        out.push(':');
        gen_value(rng, 1, out);
    }
    if rng.chance(15) {
        // duplicate key: last value wins in both parsers
        sep(out, &mut first);
        out.push_str("\"model\":\"dup\"");
    }
    out.push('}');
}

/// Structural mutations shared by both fuzz targets.
fn mutate(rng: &mut Rng, bytes: &mut Vec<u8>) {
    match rng.below(6) {
        0 => {} // passthrough: the unmutated document must be accepted
        1 => {
            // truncate
            if !bytes.is_empty() {
                bytes.truncate(rng.below(bytes.len()));
            }
        }
        2 => {
            // flip 1–4 bytes
            for _ in 0..1 + rng.below(4) {
                if bytes.is_empty() {
                    break;
                }
                let i = rng.below(bytes.len());
                bytes[i] ^= 1 << rng.below(8);
            }
        }
        3 => {
            // insert random bytes
            for _ in 0..1 + rng.below(3) {
                let i = rng.below(bytes.len() + 1);
                bytes.insert(i, rng.next() as u8);
            }
        }
        4 => {
            // delete a byte
            if !bytes.is_empty() {
                bytes.remove(rng.below(bytes.len()));
            }
        }
        _ => {
            // splice a random self-slice into a random position
            if bytes.len() >= 2 {
                let a = rng.below(bytes.len());
                let b = (a + 1 + rng.below(16)).min(bytes.len());
                let slice: Vec<u8> = bytes[a..b].to_vec();
                let at = rng.below(bytes.len());
                bytes.splice(at..at, slice);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// HTTP request generator + mutations
// ---------------------------------------------------------------------------

const METHODS: &[&str] = &["GET", "POST", "DELETE", "PUT", "HEAD", "patch"];
const PATHS: &[&str] = &[
    "/predict",
    "/metrics",
    "/metrics?format=prometheus",
    "/models",
    "/models/bench/profile",
    "/healthz",
    "/readyz",
    "/a/b/c",
];
const RID_CHARS: &[u8] = b"abcXYZ019._-@! \t\x7f";

fn gen_request(rng: &mut Rng, out: &mut Vec<u8>) {
    let nl = if rng.chance(70) { "\r\n" } else { "\n" };
    let method = *rng.pick(METHODS);
    let path = *rng.pick(PATHS);
    let version = if rng.chance(85) { "HTTP/1.1" } else { "HTTP/1.0" };
    out.extend_from_slice(format!("{method} {path} {version}{nl}").as_bytes());
    out.extend_from_slice(format!("Host: fuzz{nl}").as_bytes());
    let mut body = String::new();
    if rng.chance(60) {
        if rng.chance(70) {
            gen_predict_doc(rng, &mut body);
        } else {
            gen_value(rng, 0, &mut body);
        }
    }
    if !body.is_empty() || rng.chance(30) {
        out.extend_from_slice(format!("Content-Length: {}{nl}", body.len()).as_bytes());
        out.extend_from_slice(format!("Content-Type: application/json{nl}").as_bytes());
    }
    if rng.chance(40) {
        let n = 1 + rng.below(70);
        let rid: Vec<u8> = (0..n).map(|_| *rng.pick(RID_CHARS)).collect();
        out.extend_from_slice(b"X-Request-Id: ");
        out.extend_from_slice(&rid);
        out.extend_from_slice(nl.as_bytes());
    }
    if rng.chance(30) {
        out.extend_from_slice(format!("X-Deadline-Ms: {}{nl}", 1 + rng.below(10_000)).as_bytes());
    }
    if rng.chance(30) {
        let c = if rng.chance(50) { "close" } else { "keep-alive" };
        out.extend_from_slice(format!("Connection: {c}{nl}").as_bytes());
    }
    out.extend_from_slice(nl.as_bytes());
    out.extend_from_slice(body.as_bytes());
}

/// Header-splicing mutation: inject a pathological header line at a
/// random line boundary in the head.
const SPLICE_HEADERS: &[&str] = &[
    "Content-Length: 18446744073709551616",
    "Content-Length: -1",
    "Content-Length: 99999999",
    "Content-Length: two",
    "X-Deadline-Ms: 0",
    "X-Deadline-Ms: -5",
    "Connection: close",
    "X-Request-Id: @@@@@@@@",
    ": empty-name",
    "No-Colon-Header",
];

fn splice_header(rng: &mut Rng, bytes: &mut Vec<u8>) {
    // find line starts within the head (up to the first blank line)
    let mut starts = vec![0usize];
    let mut i = 0;
    while i + 1 < bytes.len() {
        if bytes[i] == b'\n' {
            starts.push(i + 1);
            if bytes[i + 1] == b'\n' || (i + 2 < bytes.len() && bytes[i + 1] == b'\r') {
                break;
            }
        }
        i += 1;
    }
    let at = starts[rng.below(starts.len())];
    let line = if rng.chance(20) {
        // oversized line → the framer's 431 per-line bound
        format!("X-Big: {}\r\n", "a".repeat(9000))
    } else if rng.chance(10) {
        // header flood → the framer's 64-line bound
        "X-Flood: 1\r\n".repeat(70)
    } else {
        format!("{}\r\n", rng.pick(SPLICE_HEADERS))
    };
    bytes.splice(at..at, line.into_bytes());
}

// ---------------------------------------------------------------------------
// fuzz: FrameParser
// ---------------------------------------------------------------------------

#[test]
fn fuzz_frame_parser_structure_aware() {
    let master = master_seed();
    let cases = case_count();
    eprintln!("frame-parser fuzz: seed=0x{master:x} cases={cases}");
    for case in 0..cases {
        let mut rng = Rng::new(case_seed(master, case));
        // 1–3 pipelined requests on one "connection"
        let copies = 1 + rng.below(3);
        let mut input = Vec::new();
        for _ in 0..copies {
            gen_request(&mut rng, &mut input);
        }
        let mutated = rng.below(10);
        match mutated {
            0..=5 => mutate(&mut rng, &mut input),
            6 => splice_header(&mut rng, &mut input),
            _ => {} // pristine
        }
        let pristine = mutated >= 7;
        let max_body = if rng.chance(20) { 512 } else { 8 << 20 };
        let input_c = input.clone();
        run_case(master, case, &input_c, move || {
            let mut p = FrameParser::new(max_body);
            let mut fed = 0usize;
            let mut frames = 0usize;
            let mut errored = false;
            'feed: while fed < input.len() {
                // chunk-boundary shuffling: deliver 1..=64 bytes at a time
                let n = (1 + rng.below(64)).min(input.len() - fed);
                p.feed(&input[fed..fed + n]);
                fed += n;
                loop {
                    match p.next_frame() {
                        Ok(None) => break,
                        Ok(Some(f)) => {
                            assert!(f.method.len() <= 16, "method too long: {:?}", f.method);
                            assert!(f.path.len() <= 256, "path too long");
                            assert!(f.body.len() <= max_body, "body exceeds max_body");
                            if let Some(rid) = f.request_id {
                                assert!(rid.len() <= 64, "request id too long: {rid:?}");
                                assert!(
                                    rid.bytes().all(|b| b.is_ascii_alphanumeric()
                                        || b == b'.'
                                        || b == b'_'
                                        || b == b'-'),
                                    "unsanitized request id {rid:?}"
                                );
                            }
                            if let Some(d) = f.deadline_ms {
                                assert!(d > 0, "zero deadline yielded");
                            }
                            frames += 1;
                            p.consume();
                            assert!(frames <= 1000, "frame explosion");
                        }
                        Err(e) => {
                            assert!(
                                matches!(e.status, 400 | 413 | 431),
                                "uncoded rejection: status {} ({})",
                                e.status,
                                e.msg
                            );
                            assert!(!e.msg.is_empty(), "empty rejection message");
                            errored = true;
                            break 'feed;
                        }
                    }
                }
            }
            if pristine && max_body == 8 << 20 {
                // an unmutated request stream must frame completely
                assert!(!errored, "pristine request rejected");
                assert_eq!(frames, copies, "pristine request stream under-framed");
            }
        });
    }
}

// ---------------------------------------------------------------------------
// fuzz: streaming lexer ≡ tree parser (+ PredictVisitor extraction)
// ---------------------------------------------------------------------------

/// The tree-side oracle for [`PredictVisitor`]: exactly the field
/// extraction `handle_predict` performs on the parsed tree.
fn check_visitor_against_tree(bytes: &[u8], tree: &Json) {
    let mut v = PredictVisitor::new(Vec::new());
    let mut lx = Lexer::new();
    lx.lex(bytes, &mut v).expect("lexer rejected a doc the tree parser accepted");
    let m = tree.get("model");
    if m.is_null() {
        assert!(!v.model_seen(), "visitor saw a model the tree treats as absent");
    } else {
        match m.as_str() {
            None => assert!(
                v.model_seen() && v.model_bad(),
                "non-string model not flagged by visitor"
            ),
            Some(name) if name.len() > MAX_MODEL_NAME => {
                assert!(v.model_overflow(), "oversized model name not flagged");
                assert_eq!(v.model(), None);
            }
            Some(name) => {
                assert!(!v.model_bad(), "valid model flagged bad");
                assert_eq!(v.model(), Some(name), "visitor extracted a different model");
            }
        }
    }
    match tree.get("features").as_f32_vec() {
        Some(expect) => {
            assert!(v.features_ok(), "valid features rejected by visitor");
            assert_eq!(v.into_features(), expect, "visitor extracted different features");
        }
        None => assert!(!v.features_ok(), "invalid features accepted by visitor"),
    }
}

#[test]
fn fuzz_json_lexer_differential() {
    let master = master_seed();
    let cases = case_count();
    eprintln!("json-lexer fuzz: seed=0x{master:x} cases={cases}");
    for case in 0..cases {
        // decorrelate from the frame-parser test's per-case streams
        let mut rng = Rng::new(case_seed(master, case) ^ 0x6a50_6e5f_7374_7265);
        let mut doc = String::new();
        if rng.chance(60) {
            gen_predict_doc(&mut rng, &mut doc);
        } else {
            gen_value(&mut rng, 0, &mut doc);
        }
        let mut bytes = doc.into_bytes();
        mutate(&mut rng, &mut bytes);
        let input = bytes.clone();
        run_case(master, case, &input, move || {
            let lexed = lex_to_tree(&bytes);
            match std::str::from_utf8(&bytes) {
                Err(_) => {
                    // non-UTF-8 can never survive the lexer: strings are
                    // validated and everything structural is ASCII
                    assert!(lexed.is_err(), "lexer accepted non-utf8 input");
                }
                Ok(s) => match json::parse(s) {
                    Ok(tree) => {
                        let built =
                            lexed.expect("lexer rejected a doc the tree parser accepted");
                        assert_eq!(built, tree, "lexer rebuilt a different tree");
                        check_visitor_against_tree(&bytes, &tree);
                    }
                    Err(e) => assert!(
                        lexed.is_err(),
                        "lexer accepted a doc the tree parser rejected ({e})"
                    ),
                },
            }
        });
    }
}

// ---------------------------------------------------------------------------
// curated property corpora: the documented edge cases, always exercised
// even at low fuzz budgets
// ---------------------------------------------------------------------------

#[test]
fn lexer_matches_tree_parser_on_valid_corpus() {
    let nested_open = "[".repeat(64);
    let nested_close = "]".repeat(64);
    let deep = format!("{nested_open}1{nested_close}");
    let corpus: Vec<&str> = vec![
        "0",
        "-0",
        "null",
        "true",
        "false",
        "\"\"",
        "[]",
        "{}",
        "[[]]",
        "{\"a\":{}}",
        " { \"a\" : [ 1 , 2 ] } ",
        "1e308",
        "-1e-308",
        "5e-324",
        "1.7976931348623157e308",
        "1e999",
        "123456789012345678901234567890",
        "9007199254740993",
        "01",
        "0.0001E+5",
        "-3e-2",
        "\"\\u0041\\u00e9\\ud83d\\ude00\"",
        "\"\\\"\\\\\\/\\b\\f\\n\\r\\t\"",
        "\"é🦀\"",
        "{\"model\":\"m\",\"features\":[1,2.5,-3e-2]}",
        "{\"model\":null,\"features\":[]}",
        "{\"a\":1,\"a\":2}",
        "[null,true,false,0,\"x\",[],{}]",
        &deep,
    ];
    for doc in corpus {
        let tree = json::parse(doc).unwrap_or_else(|e| panic!("tree parser rejected {doc:?}: {e}"));
        let built = lex_to_tree(doc.as_bytes())
            .unwrap_or_else(|e| panic!("lexer rejected {doc:?}: {e}"));
        assert_eq!(built, tree, "divergent trees for {doc:?}");
    }
}

#[test]
fn lexer_and_tree_parser_reject_same_invalid_corpus() {
    let corpus: &[&str] = &[
        "",
        "   ",
        "{",
        "}",
        "[",
        "]",
        "[1,]",
        "{\"a\":}",
        "{\"a\" 1}",
        "{\"a\":1,}",
        "{1:2}",
        "[1 2]",
        "tru",
        "nul",
        "falsy",
        "+1",
        ".5",
        "-",
        "1e",
        "\"abc",
        "\"\\x\"",
        "\"\\u12\"",
        "\"\\ud800\"",
        "\"\\ud800\\u0041\"",
        "\"a\nb\"",
        "[1]]",
        "1 2",
        "\"a\"b",
        "{\"a\":1}}",
    ];
    for doc in corpus {
        assert!(json::parse(doc).is_err(), "tree parser accepted invalid {doc:?}");
        assert!(lex_to_tree(doc.as_bytes()).is_err(), "lexer accepted invalid {doc:?}");
    }
}

/// Non-UTF-8 byte sequences (not expressible as `&str`) must be rejected
/// by the lexer wherever they appear.
#[test]
fn lexer_rejects_non_utf8_bytes() {
    let cases: &[&[u8]] = &[
        b"\"\xff\"",
        b"\"a\xc3\"",
        b"[\xff]",
        b"{\"a\xf0\x28\":1}",
        b"\xef\xbb\xbf1", // BOM is not whitespace
    ];
    for c in cases {
        assert!(lex_to_tree(c).is_err(), "lexer accepted non-utf8 {:?}", escape(c));
    }
}
