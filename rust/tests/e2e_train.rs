//! Integration tests over the full stack: AOT artifacts → PJRT runtime →
//! coordinator → FXR export → pure-Rust decrypted inference.
//!
//! These need `make artifacts` (default set) to have run; they skip (pass
//! vacuously with a note) when artifacts are absent so `cargo test` works
//! on a fresh checkout.

use std::path::Path;

use flexor::coordinator::{export_bundle, export_fxr, MetricsSink, Schedule, TrainSession};
use flexor::data::{self, Batcher, Split};
use flexor::inference::InferenceModel;
use flexor::runtime::{Manifest, Runtime};

fn artifacts_root() -> Option<&'static Path> {
    // tests run from the crate root
    let p = Path::new("artifacts");
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("SKIP: artifacts/manifest.json missing — run `make artifacts`");
        None
    }
}

fn runtime() -> Runtime {
    // PjRtClient is not Send/Sync (Rc internals) — one client per test.
    Runtime::cpu().expect("pjrt cpu client")
}

#[test]
fn quickstart_mlp_trains_and_learns() {
    let Some(root) = artifacts_root() else { return };
    let man = Manifest::load(root).unwrap();
    let mut session = TrainSession::new(&runtime(), &man, "quickstart_mlp").unwrap();
    assert_eq!(session.meta.model, "mlp");
    assert!((session.meta.bits_per_weight - 0.8).abs() < 0.05);

    let ds = data::by_name("digits", 0).unwrap();
    let schedule = Schedule::mnist(1e-3, 50);
    let mut sink = MetricsSink::new();
    let ev = session
        .train_loop(ds.as_ref(), &schedule, 120, 60, 256, &mut sink)
        .unwrap();
    // learning signal: late loss well below early loss, accuracy above chance
    let early = sink.train[..10].iter().map(|r| r.loss).sum::<f32>() / 10.0;
    let late = sink.tail_loss(10).unwrap();
    assert!(late < early * 0.8, "no learning: {early} -> {late}");
    assert!(ev.top1 > 0.2, "top1 {} not above chance", ev.top1);
    assert!(ev.top5 >= ev.top1);
    assert_eq!(session.steps_done, 120);
}

#[test]
fn eval_is_deterministic_and_state_feedback_works() {
    let Some(root) = artifacts_root() else { return };
    let man = Manifest::load(root).unwrap();
    let mut session = TrainSession::new(&runtime(), &man, "quickstart_mlp").unwrap();
    let ds = data::by_name("digits", 1).unwrap();
    let (xs, ys) = Batcher::eval_set(ds.as_ref(), Split::Test, 128);
    let e1 = session.eval(&xs, &ys, 100.0, 0.0).unwrap();
    let e2 = session.eval(&xs, &ys, 100.0, 0.0).unwrap();
    assert_eq!(e1, e2, "eval must be deterministic");

    // one train step must change the state (loss finite, params move)
    let w_before = session.leaf_f32(0).unwrap();
    let mut b = Batcher::new(ds.as_ref(), Split::Train, session.meta.batch, 512);
    let (x, y) = b.next_batch();
    let (loss, acc) = session.step(&x, &y, 1e-3, 100.0, 0.0).unwrap();
    assert!(loss.is_finite() && (0.0..=1.0).contains(&acc));
    let w_after = session.leaf_f32(0).unwrap();
    assert_ne!(w_before, w_after, "params did not update");
}

#[test]
fn checkpoint_roundtrip_preserves_eval() {
    let Some(root) = artifacts_root() else { return };
    let man = Manifest::load(root).unwrap();
    let mut session = TrainSession::new(&runtime(), &man, "quickstart_mlp").unwrap();
    let ds = data::by_name("digits", 2).unwrap();
    let mut b = Batcher::new(ds.as_ref(), Split::Train, session.meta.batch, 512);
    for _ in 0..5 {
        let (x, y) = b.next_batch();
        session.step(&x, &y, 1e-3, 100.0, 0.0).unwrap();
    }
    let (xs, ys) = Batcher::eval_set(ds.as_ref(), Split::Test, 128);
    let before = session.eval(&xs, &ys, 100.0, 0.0).unwrap();

    let ckpt = std::env::temp_dir().join("flexor_e2e_ckpt.bin");
    session.save_checkpoint(&ckpt).unwrap();
    // perturb by training more, then restore
    for _ in 0..5 {
        let (x, y) = b.next_batch();
        session.step(&x, &y, 1e-2, 100.0, 0.0).unwrap();
    }
    let perturbed = session.eval(&xs, &ys, 100.0, 0.0).unwrap();
    session.load_checkpoint(&ckpt).unwrap();
    let restored = session.eval(&xs, &ys, 100.0, 0.0).unwrap();
    assert_eq!(before, restored);
    // (the perturbed eval usually differs; don't assert hard inequality)
    let _ = perturbed;
    std::fs::remove_file(ckpt).ok();
}

#[test]
fn fxr_export_matches_training_state_and_rust_inference_agrees() {
    let Some(root) = artifacts_root() else { return };
    let man = Manifest::load(root).unwrap();
    let mut session = TrainSession::new(&runtime(), &man, "quickstart_mlp").unwrap();
    let ds = data::by_name("digits", 3).unwrap();
    let schedule = Schedule::mnist(1e-3, 50);
    let mut sink = MetricsSink::new();
    let ev = session
        .train_loop(ds.as_ref(), &schedule, 150, 150, 256, &mut sink)
        .unwrap();

    // container stats must reproduce the meta's storage accounting
    let fxr = export_fxr(&session).unwrap();
    let stats = fxr.stats();
    assert!((stats.bits_per_weight - session.meta.bits_per_weight).abs() < 1e-9);

    // FXR roundtrip through bytes
    let bytes = fxr.to_bytes();
    let back = flexor::flexor::fxr::Container::from_bytes(&bytes).unwrap();
    assert_eq!(back.layers.len(), fxr.layers.len());

    // full bundle + rust inference: accuracy must match the HLO eval closely
    let dir = std::env::temp_dir().join("flexor_e2e_bundle");
    export_bundle(&session, &dir, "qs").unwrap();
    let model = InferenceModel::load(&dir, "qs").unwrap();
    let n = 256;
    let (xs, ys) = Batcher::eval_set(ds.as_ref(), Split::Test, n);
    let preds = model.predict(&xs, n).unwrap();
    let top1 = preds.iter().zip(&ys).filter(|(p, y)| p == y).count() as f32 / n as f32;
    assert!(
        (top1 - ev.top1).abs() < 0.05,
        "rust inference top1 {top1} vs HLO eval {}",
        ev.top1
    );
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn pallas_artifact_matches_jnp_artifact() {
    // The two quickstart configs differ only in use_pallas (L1 kernels on
    // the train path); from identical init + identical data they must
    // produce near-identical losses.
    let Some(root) = artifacts_root() else { return };
    let man = Manifest::load(root).unwrap();
    if !man.configs.contains_key("quickstart_mlp_pallas") {
        eprintln!("SKIP: quickstart_mlp_pallas not built");
        return;
    }
    let mut a = TrainSession::new(&runtime(), &man, "quickstart_mlp").unwrap();
    let mut b = TrainSession::new(&runtime(), &man, "quickstart_mlp_pallas").unwrap();
    let ds = data::by_name("digits", 4).unwrap();
    let mut batcher = Batcher::new(ds.as_ref(), Split::Train, a.meta.batch, 512);
    for step in 0..5 {
        let (x, y) = batcher.next_batch();
        let (la, _) = a.step(&x, &y, 1e-3, 100.0, 0.0).unwrap();
        let (lb, _) = b.step(&x, &y, 1e-3, 100.0, 0.0).unwrap();
        assert!(
            (la - lb).abs() < 1e-3 * (1.0 + la.abs()),
            "step {step}: jnp loss {la} vs pallas loss {lb}"
        );
    }
}
