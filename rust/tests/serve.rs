//! Integration tests for the serving subsystem: registry → admission
//! queue → worker pool → HTTP front-end, driven over real loopback
//! sockets against a synthetic encrypted bundle (no AOT artifacts or
//! PJRT runtime needed — the bundle still goes through the full
//! decrypt-at-load + binary-code forward path).

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::{Duration, Instant};

use flexor::coordinator::{export_synthetic_mlp_bundle, export_synthetic_resnet_bundle};
use flexor::inference::InferenceModel;
use flexor::serve::{
    http, BatchQueue, Registry, Request, Responder, ServeConfig, ServeMetrics, Server,
    WorkerPool,
};
use flexor::substrate::fault::{self, FaultPlan};
use flexor::substrate::json::{self, Json};
use flexor::substrate::prng::Pcg32;
use flexor::substrate::trace::TraceMode;

const D_IN: usize = 16;

fn bundle_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("flexor_serve_{tag}_{}", std::process::id()))
}

fn start_server(tag: &str, cfg: ServeConfig) -> (Server, PathBuf) {
    let dir = bundle_dir(tag);
    export_synthetic_mlp_bundle(&dir, "served", 7, D_IN, &[32, 24], 10).unwrap();
    let registry = Registry::new();
    registry.load("served", &dir, "served").unwrap();
    let server = Server::start("127.0.0.1:0", registry, cfg).unwrap();
    (server, dir)
}

fn predict_body(model: &str, features: &[f32]) -> String {
    Json::obj(vec![
        ("model", Json::str(model)),
        ("features", Json::arr(features.iter().map(|&v| Json::num(v)))),
    ])
    .to_string()
}

fn post_predict(addr: SocketAddr, body: &str) -> (u16, Json) {
    let (status, resp) = http::client::request(addr, "POST", "/predict", Some(body)).unwrap();
    (status, json::parse(&resp).unwrap())
}

/// ≥ 64 concurrent single-example requests from ≥ 8 client threads: every
/// response must match a direct `InferenceModel::predict`, and `/metrics`
/// must show the admission queue coalesced them (mean batch size > 1).
#[test]
fn concurrent_predictions_match_direct_inference_and_coalesce() {
    const CLIENTS: usize = 16;
    const PER_CLIENT: usize = 4; // 64 requests total

    let cfg = ServeConfig {
        workers: 2,
        max_batch: 32,
        max_wait_us: 10_000,
        queue_capacity: 256,
        intra_threads: 2,
        ..ServeConfig::default()
    };
    let (server, dir) = start_server("e2e", cfg);
    let addr = server.local_addr();

    // independent reference model, loaded from the same bundle
    let reference = InferenceModel::load(&dir, "served").unwrap();
    let mut rng = Pcg32::seeded(99);
    let inputs: Vec<Vec<f32>> = (0..CLIENTS * PER_CLIENT)
        .map(|_| (0..D_IN).map(|_| rng.normal()).collect())
        .collect();
    let expected: Vec<i32> = inputs
        .iter()
        .map(|x| reference.predict(x, 1).unwrap()[0])
        .collect();

    let handles: Vec<_> = (0..CLIENTS)
        .map(|c| {
            let mine: Vec<(usize, Vec<f32>)> = (c * PER_CLIENT..(c + 1) * PER_CLIENT)
                .map(|i| (i, inputs[i].clone()))
                .collect();
            thread::spawn(move || -> Vec<(usize, i32, usize)> {
                mine.into_iter()
                    .map(|(i, x)| {
                        let (status, v) = post_predict(addr, &predict_body("served", &x));
                        assert_eq!(status, 200, "request {i}: {v}");
                        let pred = v.get("prediction").as_i64().unwrap() as i32;
                        let batch = v.get("batch_size").as_usize().unwrap();
                        assert!(v.get("latency_ms").as_f64().unwrap() >= 0.0);
                        (i, pred, batch)
                    })
                    .collect()
            })
        })
        .collect();

    let mut max_batch_seen = 0usize;
    for h in handles {
        for (i, pred, batch) in h.join().unwrap() {
            assert_eq!(pred, expected[i], "request {i} diverged from direct predict");
            max_batch_seen = max_batch_seen.max(batch);
        }
    }

    // server-side metrics: all 64 served, none failed, and coalesced
    let (status, m) = http::client::request(addr, "GET", "/metrics", None).unwrap();
    assert_eq!(status, 200);
    let mj = json::parse(&m).unwrap();
    assert_eq!(mj.get("requests_total").as_usize(), Some(CLIENTS * PER_CLIENT));
    assert_eq!(mj.get("errors_total").as_usize(), Some(0));
    assert_eq!(mj.get("examples_total").as_usize(), Some(CLIENTS * PER_CLIENT));
    let mean_batch = mj.get("mean_batch_size").as_f64().unwrap();
    assert!(
        mean_batch > 1.0,
        "batcher did not coalesce: mean batch {mean_batch}, hist {}",
        mj.get("batch_size_hist")
    );
    assert!(max_batch_seen > 1, "no response reported a shared forward pass");
    assert!(mj.get("latency_ms").get("p99").as_f64().unwrap() > 0.0);

    server.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// Whole-bundle engine equivalence: the packed parallel fused forward
/// must agree with the pre-engine separate-pass reference composition on
/// both synthetic bundle families (mlp and the conv-heavy resnet).
#[test]
fn packed_engine_matches_reference_forward_on_bundles() {
    let mut rng = Pcg32::seeded(1234);

    let dir = bundle_dir("engine_mlp");
    export_synthetic_mlp_bundle(&dir, "m", 21, D_IN, &[40, 24], 10).unwrap();
    let mlp = InferenceModel::load(&dir, "m").unwrap();
    let x: Vec<f32> = (0..6 * D_IN).map(|_| rng.normal()).collect();
    let fused = mlp.forward(&x, 6).unwrap();
    let reference = mlp.forward_reference(&x, 6).unwrap();
    assert_eq!(fused.len(), reference.len());
    for (i, (a, b)) in fused.iter().zip(&reference).enumerate() {
        assert!(
            (a - b).abs() <= 1e-3 * (1.0 + b.abs()),
            "mlp logit {i}: fused {a} vs reference {b}"
        );
    }
    assert_eq!(mlp.predict(&x, 6).unwrap().len(), 6);
    std::fs::remove_dir_all(&dir).ok();

    let dir = bundle_dir("engine_resnet");
    export_synthetic_resnet_bundle(&dir, "r", 22, "resnet8", 8, 10).unwrap();
    let resnet = InferenceModel::load(&dir, "r").unwrap();
    let feat = 8 * 8 * 3;
    let x: Vec<f32> = (0..3 * feat).map(|_| rng.normal()).collect();
    let fused = resnet.forward(&x, 3).unwrap();
    let reference = resnet.forward_reference(&x, 3).unwrap();
    assert_eq!(fused.len(), 3 * 10);
    assert_eq!(reference.len(), 3 * 10);
    for (i, (a, b)) in fused.iter().zip(&reference).enumerate() {
        assert!(a.is_finite(), "resnet fused logit {i} not finite: {a}");
        assert!(
            (a - b).abs() <= 1e-3 * (1.0 + b.abs()),
            "resnet logit {i}: fused {a} vs reference {b}"
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn models_endpoint_reports_storage_stats() {
    let (server, dir) = start_server("models", ServeConfig::default());
    let addr = server.local_addr();

    let (status, body) = http::client::request(addr, "GET", "/models", None).unwrap();
    assert_eq!(status, 200);
    let v = json::parse(&body).unwrap();
    let m = v.get("models").at(0);
    assert_eq!(m.get("name").as_str(), Some("served"));
    assert_eq!(m.get("model").as_str(), Some("mlp"));
    assert_eq!(m.get("feature_len").as_usize(), Some(D_IN));
    assert_eq!(m.get("num_classes").as_usize(), Some(10));
    // q=1, n_in=8, n_out=10 ⇒ ~0.8 bits/weight, ~35-40× compression
    let bpw = m.get("bits_per_weight").as_f64().unwrap();
    assert!((0.75..0.95).contains(&bpw), "bits/weight {bpw}");
    assert!(m.get("compression_ratio").as_f64().unwrap() > 10.0);
    assert!(m.get("load_ms").as_f64().unwrap() >= 0.0);
    // per-model resident-bytes accounting (dense default mode)
    assert_eq!(m.get("compute_mode").as_str(), Some("dense"));
    let qb = m.get("quantized_weight_bytes").as_usize().unwrap();
    let fpb = m.get("fp_weight_bytes").as_usize().unwrap();
    assert!(qb > 0 && fpb > 0, "resident accounting missing: q={qb} fp={fpb}");
    assert_eq!(m.get("resident_bytes").as_usize(), Some(qb + fpb));

    let (status, body) = http::client::request(addr, "GET", "/healthz", None).unwrap();
    assert_eq!(status, 200);
    assert_eq!(json::parse(&body).unwrap().get("status").as_str(), Some("ok"));

    server.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn malformed_requests_get_4xx_not_hangs() {
    let (server, dir) = start_server("errors", ServeConfig::default());
    let addr = server.local_addr();
    let good: Vec<f32> = vec![0.5; D_IN];

    // bad JSON
    let (status, v) = post_predict(addr, "{not json");
    assert_eq!(status, 400, "{v}");
    // unknown model
    let (status, v) = post_predict(addr, &predict_body("ghost", &good));
    assert_eq!(status, 404, "{v}");
    // wrong feature count
    let (status, v) = post_predict(addr, &predict_body("served", &good[..3]));
    assert_eq!(status, 400, "{v}");
    // missing features field
    let (status, v) = post_predict(addr, r#"{"model":"served"}"#);
    assert_eq!(status, 400, "{v}");
    // non-numeric features
    let (status, v) = post_predict(addr, r#"{"model":"served","features":[1,"x"]}"#);
    assert_eq!(status, 400, "{v}");
    // unknown route + bad method
    let (status, _) = http::client::request(addr, "GET", "/nope", None).unwrap();
    assert_eq!(status, 404);
    let (status, _) = http::client::request(addr, "DELETE", "/predict", None).unwrap();
    assert_eq!(status, 405);

    // a model-less request works while exactly one model is registered
    let body = format!(
        r#"{{"features":{}}}"#,
        Json::arr(good.iter().map(|&v| Json::num(v)))
    );
    let (status, v) = post_predict(addr, &body);
    assert_eq!(status, 200, "{v}");

    // and the server still serves correct traffic afterwards
    let (status, _) = post_predict(addr, &predict_body("served", &good));
    assert_eq!(status, 200);

    // the 5 predict rejections are visible in /metrics, separate from
    // the 2 served requests
    let (status, m) = http::client::request(addr, "GET", "/metrics", None).unwrap();
    assert_eq!(status, 200);
    let mj = json::parse(&m).unwrap();
    assert_eq!(mj.get("rejected_total").as_usize(), Some(5));
    assert_eq!(mj.get("requests_total").as_usize(), Some(2));
    assert_eq!(mj.get("errors_total").as_usize(), Some(0));

    server.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// Graceful drain: a request admitted *before* `begin_drain` completes
/// normally (the queue keeps draining), late arrivals get a coded
/// `503 draining`, `/readyz` flips to not-ready, and `/healthz` stays
/// `200` (the process is alive, just not accepting work).
#[test]
fn drain_completes_inflight_and_rejects_late_arrivals() {
    let cfg = ServeConfig {
        workers: 1,
        max_batch: 32,
        max_wait_us: 300_000, // long linger: the in-flight request stays queued
        ..ServeConfig::default()
    };
    let (server, dir) = start_server("drain", cfg);
    let addr = server.local_addr();
    let good: Vec<f32> = vec![0.5; D_IN];

    // in-flight request: admitted now, served after the linger window
    let body = predict_body("served", &good);
    let inflight = thread::spawn(move || post_predict(addr, &body));
    thread::sleep(Duration::from_millis(60));

    server.begin_drain();
    assert!(server.is_draining());

    // late arrival → 503 with the stable "draining" code
    let (status, v) = post_predict(addr, &predict_body("served", &good));
    assert_eq!(status, 503, "{v}");
    assert_eq!(v.get("code").as_str(), Some("draining"), "{v}");

    // readiness flips; liveness does not
    let (status, body) = http::client::request(addr, "GET", "/readyz", None).unwrap();
    assert_eq!(status, 503);
    let r = json::parse(&body).unwrap();
    assert_eq!(r.get("ready").as_bool(), Some(false), "{r}");
    assert_eq!(r.get("draining").as_bool(), Some(true), "{r}");
    let (status, _) = http::client::request(addr, "GET", "/healthz", None).unwrap();
    assert_eq!(status, 200);

    // the pre-drain request still completes with a real prediction
    let (status, v) = inflight.join().unwrap();
    assert_eq!(status, 200, "in-flight request dropped during drain: {v}");
    assert!(v.get("prediction").as_i64().is_some(), "{v}");

    server.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// Bodies over the configured bound get `413` + the stable
/// `body_too_large` code without the server buffering them; right-sized
/// traffic is unaffected.
#[test]
fn oversized_body_rejected_with_413() {
    let cfg = ServeConfig { max_body_bytes: Some(256), ..ServeConfig::default() };
    let (server, dir) = start_server("bodycap", cfg);
    let addr = server.local_addr();

    let huge = "x".repeat(300);
    let (status, resp) =
        http::client::request(addr, "POST", "/predict", Some(&huge)).unwrap();
    assert_eq!(status, 413, "{resp}");
    let v = json::parse(&resp).unwrap();
    assert_eq!(v.get("code").as_str(), Some("body_too_large"), "{v}");
    assert!(!v.get("request_id").as_str().unwrap_or("").is_empty(), "{v}");

    // a normal-sized request on the same server still serves
    let good: Vec<f32> = vec![0.5; D_IN];
    let (status, _) = post_predict(addr, &predict_body("served", &good));
    assert_eq!(status, 200);

    let (_, m) = http::client::request(addr, "GET", "/metrics", None).unwrap();
    let mj = json::parse(&m).unwrap();
    assert_eq!(mj.get("rejected_total").as_usize(), Some(1));
    assert_eq!(mj.get("requests_total").as_usize(), Some(1));

    server.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// Every 4xx body is structured — stable `code`, human `error`, and the
/// client's `X-Request-Id` echoed back so failures correlate across
/// client and server logs.
#[test]
fn error_bodies_are_structured_and_echo_request_id() {
    let (server, dir) = start_server("errbody", ServeConfig::default());
    let addr = server.local_addr();

    let cases: &[(&str, u16, &str)] = &[
        ("{not json", 400, "bad_request"),
        (r#"{"model":"ghost","features":[1.0]}"#, 404, "unknown_model"),
        (r#"{"model":"served"}"#, 400, "bad_request"),
        (r#"{"model":"served","features":[1,"x"]}"#, 400, "bad_request"),
    ];
    for (i, (body, want_status, want_code)) in cases.iter().enumerate() {
        let rid = format!("case-{i}.test");
        let (status, headers, resp) = http::client::request_with_headers(
            addr,
            "POST",
            "/predict",
            &[("X-Request-Id", &rid)],
            Some(body),
        )
        .unwrap();
        assert_eq!(status, *want_status, "case {i}: {resp}");
        let v = json::parse(&resp).unwrap();
        assert_eq!(v.get("code").as_str(), Some(*want_code), "case {i}: {v}");
        assert!(!v.get("error").as_str().unwrap_or("").is_empty(), "case {i}: {v}");
        assert_eq!(v.get("request_id").as_str(), Some(rid.as_str()), "case {i}: {v}");
        let echoed = headers
            .iter()
            .find(|(k, _)| k == "x-request-id")
            .map(|(_, v)| v.as_str());
        assert_eq!(echoed, Some(rid.as_str()), "case {i}: header not echoed");
    }

    server.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// Worker-level deadline semantics with a full queue: expired and live
/// requests interleaved in one popped batch — the expired ones are shed
/// with `deadline_exceeded` (no forward pass), the live ones are served,
/// and the shed/served split lands in the metrics counters.
#[test]
fn worker_sheds_expired_requests_and_serves_the_rest() {
    let dir = bundle_dir("expiry");
    export_synthetic_mlp_bundle(&dir, "served", 7, D_IN, &[32, 24], 10).unwrap();
    let registry = Registry::new();
    let entry = registry.load("served", &dir, "served").unwrap();

    let queue: Arc<BatchQueue<Request>> = Arc::new(BatchQueue::bounded(4));
    let metrics = Arc::new(ServeMetrics::new());
    let x: Vec<f32> = vec![0.5; D_IN];

    // interleave expired / live / expired / live, then overflow
    let now = Instant::now();
    let mut rxs = Vec::new();
    for i in 0..4 {
        let (tx, rx) = mpsc::channel();
        let expired = i % 2 == 0;
        queue
            .try_push(Request {
                entry: entry.clone(),
                features: x.clone(),
                respond: Responder::Channel(tx),
                enqueued: now,
                // `now` is already in the past by the time a worker pops
                deadline: expired.then_some(now),
            })
            .map_err(|_| ())
            .unwrap();
        rxs.push((expired, rx));
    }
    let (tx, _rx) = mpsc::channel();
    let overflow = Request {
        entry: entry.clone(),
        features: x.clone(),
        respond: Responder::Channel(tx),
        enqueued: Instant::now(),
        deadline: None,
    };
    assert!(queue.try_push(overflow).is_err(), "queue should be full");

    // tiny sleep so the pop timestamp is strictly past the deadlines
    thread::sleep(Duration::from_millis(5));
    let pool = WorkerPool::spawn(
        1,
        queue.clone(),
        metrics.clone(),
        8,
        Duration::ZERO,
        Some(TraceMode::Off),
    );

    for (i, (expired, rx)) in rxs.into_iter().enumerate() {
        let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        if expired {
            let e = resp.expect_err("expired request must not be served");
            assert_eq!(e.code.label(), "deadline_exceeded", "request {i}: {e}");
        } else {
            let p = resp.unwrap_or_else(|e| panic!("request {i} failed: {e}"));
            assert_eq!(p.model, "served");
            assert_eq!(p.batch_size, 2, "only the two live requests share the forward");
        }
    }

    let snap = metrics.snapshot(queue.len());
    assert_eq!(snap.get("deadline_expired_total").as_usize(), Some(2), "{snap}");
    assert_eq!(snap.get("requests_total").as_usize(), Some(2), "{snap}");
    assert_eq!(snap.get("errors_total").as_usize(), Some(0), "{snap}");

    queue.close();
    pool.join();
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------------
// Event-loop torture tests (DESIGN.md §14). These drive the default
// nonblocking front-end over raw sockets: byte-at-a-time framing,
// pipelining, slowloris stalls, oversized heads, keep-alive accounting,
// and queue-stall backpressure. Gated on unix, where the readiness loop
// (and its epoll backend) is the default front-end.
// ---------------------------------------------------------------------------

/// Read one HTTP/1.1 response off a raw socket: status, headers
/// (lower-cased names), and the `Content-Length`-framed body. `None` on
/// EOF before a complete response.
#[cfg(unix)]
fn read_raw_response(r: &mut BufReader<TcpStream>) -> Option<(u16, Vec<(String, String)>, String)> {
    let mut line = String::new();
    if r.read_line(&mut line).ok()? == 0 {
        return None;
    }
    let status: u16 = line.split_whitespace().nth(1)?.parse().ok()?;
    let mut headers = Vec::new();
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        if r.read_line(&mut h).ok()? == 0 {
            return None;
        }
        let t = h.trim();
        if t.is_empty() {
            break;
        }
        if let Some((k, v)) = t.split_once(':') {
            let k = k.to_ascii_lowercase();
            let v = v.trim().to_string();
            if k == "content-length" {
                content_length = v.parse().ok()?;
            }
            headers.push((k, v));
        }
    }
    let mut body = vec![0u8; content_length];
    r.read_exact(&mut body).ok()?;
    Some((status, headers, String::from_utf8(body).ok()?))
}

#[cfg(unix)]
fn raw_predict_request(rid: &str, features: &[f32]) -> Vec<u8> {
    let body = predict_body("served", features);
    format!(
        "POST /predict HTTP/1.1\r\nHost: torture\r\nX-Request-Id: {rid}\r\n\
         Content-Type: application/json\r\nContent-Length: {}\r\n\r\n{}",
        body.len(),
        body
    )
    .into_bytes()
}

#[cfg(unix)]
fn header_value(headers: &[(String, String)], name: &str) -> String {
    headers
        .iter()
        .find(|(k, _)| k == name)
        .map(|(_, v)| v.clone())
        .unwrap_or_default()
}

/// Pipelining: several requests written back-to-back in one `write` on
/// one connection must come back as in-order responses — `X-Request-Id`
/// echo proves the ordering — and the reuse shows up in the keep-alive
/// counter.
#[cfg(unix)]
#[test]
fn pipelined_requests_are_answered_in_order_on_one_connection() {
    let (server, dir) = start_server("pipeline", ServeConfig::default());
    let addr = server.local_addr();
    let good: Vec<f32> = vec![0.5; D_IN];

    let mut wire = Vec::new();
    for i in 0..3 {
        wire.extend_from_slice(&raw_predict_request(&format!("pipe-{i}"), &good));
    }
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    stream.write_all(&wire).unwrap();

    let mut reader = BufReader::new(stream);
    for i in 0..3 {
        let (status, headers, body) =
            read_raw_response(&mut reader).unwrap_or_else(|| panic!("missing response {i}"));
        assert_eq!(status, 200, "response {i}: {body}");
        assert_eq!(
            header_value(&headers, "x-request-id"),
            format!("pipe-{i}"),
            "pipelined responses out of order"
        );
        let v = json::parse(&body).unwrap();
        assert!(v.get("prediction").as_i64().is_some(), "{v}");
    }

    let (_, m) = http::client::request(addr, "GET", "/metrics", None).unwrap();
    let mj = json::parse(&m).unwrap();
    assert!(
        mj.get("keepalive_requests_total").as_usize().unwrap_or(0) >= 2,
        "pipelined reuse not counted: {mj}"
    );

    server.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// Incremental framing: a valid request split into two writes at *every*
/// byte boundary must still parse to exactly one 200. This walks the
/// resumable parser through every possible partial-read suspension
/// point (mid-request-line, mid-header, mid-body).
#[cfg(unix)]
#[test]
fn request_framing_survives_a_split_at_every_byte_boundary() {
    let cfg = ServeConfig { max_wait_us: 0, ..ServeConfig::default() };
    let (server, dir) = start_server("split", cfg);
    let addr = server.local_addr();
    let good: Vec<f32> = vec![0.25; D_IN];
    let wire = raw_predict_request("split", &good);

    for cut in 1..wire.len() {
        let mut stream = TcpStream::connect(addr).unwrap();
        stream.set_nodelay(true).ok();
        stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        stream.write_all(&wire[..cut]).unwrap();
        stream.flush().unwrap();
        stream.write_all(&wire[cut..]).unwrap();
        let mut reader = BufReader::new(stream);
        let (status, _headers, body) = read_raw_response(&mut reader)
            .unwrap_or_else(|| panic!("no response when split at byte {cut}"));
        assert_eq!(status, 200, "split at byte {cut}: {body}");
    }

    server.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// Slowloris: a client that sends a partial header block and then stalls
/// gets a coded `408 request_timeout` once the header window elapses,
/// and the connection is closed — it cannot pin a connection slot open.
#[cfg(unix)]
#[test]
fn slowloris_header_stall_gets_408_and_the_connection_closed() {
    let cfg = ServeConfig { header_timeout_ms: Some(150), ..ServeConfig::default() };
    let (server, dir) = start_server("slowloris", cfg);
    let addr = server.local_addr();

    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    stream.set_nodelay(true).ok();
    // drip a partial request head one byte per write, then stall forever
    for &b in b"POST /predict HTTP/1.1\r\nHost: slow\r\n" {
        stream.write_all(&[b]).unwrap();
    }
    let t0 = Instant::now();

    // a fast client is unaffected while the slow one stalls
    let good: Vec<f32> = vec![0.5; D_IN];
    let (status, v) = post_predict(addr, &predict_body("served", &good));
    assert_eq!(status, 200, "fast client starved by a slowloris peer: {v}");

    let mut reader = BufReader::new(stream);
    let (status, _headers, body) =
        read_raw_response(&mut reader).expect("no response for a stalled header block");
    assert_eq!(status, 408, "{body}");
    let v = json::parse(&body).unwrap();
    assert_eq!(v.get("code").as_str(), Some("request_timeout"), "{v}");
    assert!(
        t0.elapsed() >= Duration::from_millis(100),
        "408 arrived before the header window could elapse"
    );

    // after the timeout response the server hangs up
    let mut rest = Vec::new();
    reader.read_to_end(&mut rest).expect("connection not closed after 408");
    assert!(rest.is_empty(), "unexpected bytes after the 408: {rest:?}");

    server.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// Oversized heads: a single huge header line, and a head that never
/// terminates within the 16 KiB bound, both get a coded
/// `431 headers_too_large` instead of unbounded buffering.
#[cfg(unix)]
#[test]
fn oversized_header_block_rejected_with_431() {
    let (server, dir) = start_server("bighead", ServeConfig::default());
    let addr = server.local_addr();

    // one 9 KB header line: over the per-line bound
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let req = format!(
        "GET /healthz HTTP/1.1\r\nHost: t\r\nX-Big: {}\r\n\r\n",
        "a".repeat(9000)
    );
    stream.write_all(req.as_bytes()).unwrap();
    let mut reader = BufReader::new(stream);
    let (status, _h, body) = read_raw_response(&mut reader).expect("no response to big header");
    assert_eq!(status, 431, "{body}");
    assert_eq!(
        json::parse(&body).unwrap().get("code").as_str(),
        Some("headers_too_large"),
        "{body}"
    );

    // ~20 KB of headers with no terminating blank line: over the
    // whole-head bound (written as one buffer so the server drains it
    // before closing)
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut flood = b"GET /healthz HTTP/1.1\r\n".to_vec();
    let pad = format!("X-Pad: {}\r\n", "b".repeat(400));
    for _ in 0..50 {
        flood.extend_from_slice(pad.as_bytes());
    }
    stream.write_all(&flood).unwrap();
    let mut reader = BufReader::new(stream);
    let (status, _h, body) =
        read_raw_response(&mut reader).expect("no response to unterminated head");
    assert_eq!(status, 431, "{body}");
    assert_eq!(
        json::parse(&body).unwrap().get("code").as_str(),
        Some("headers_too_large"),
        "{body}"
    );

    server.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// Keep-alive accounting: five predicts through one persistent
/// [`http::client::Conn`] are exactly one accepted connection with four
/// reuses; the `/metrics` fetch itself is the second connection.
#[cfg(unix)]
#[test]
fn keep_alive_connection_reuse_shows_in_connection_metrics() {
    let (server, dir) = start_server("keepalive", ServeConfig::default());
    let addr = server.local_addr();
    let good: Vec<f32> = vec![0.5; D_IN];
    let body = predict_body("served", &good);

    let mut conn = http::client::Conn::connect(addr).unwrap();
    for i in 0..5 {
        let (status, resp) = conn.request("POST", "/predict", Some(&body)).unwrap();
        assert_eq!(status, 200, "keep-alive request {i}: {resp}");
    }

    let (status, m) = http::client::request(addr, "GET", "/metrics", None).unwrap();
    assert_eq!(status, 200);
    let mj = json::parse(&m).unwrap();
    assert_eq!(mj.get("keepalive_requests_total").as_usize(), Some(4), "{mj}");
    assert_eq!(mj.get("connections_total").as_usize(), Some(2), "{mj}");
    assert_eq!(mj.get("connections_open").as_usize(), Some(2), "{mj}");

    drop(conn);
    server.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// Backpressure regression: with a one-slot queue and a stalled worker
/// (`FLEXOR_FAULT=queue_stall` semantics, armed in-process), a client
/// pipelining four requests must see the loop *stop reading its socket*
/// — the `suspended_connections` gauge rises while the stall holds, at
/// least one request is shed with a 503, responses still come back in
/// pipeline order, and the gauge returns to zero once the queue drains.
#[cfg(unix)]
#[test]
fn queue_stall_suspends_the_connection_and_resumes_after_drain() {
    let cfg = ServeConfig {
        workers: 1,
        max_batch: 1,
        max_wait_us: 0,
        queue_capacity: 1,
        ..ServeConfig::default()
    };
    let (server, dir) = start_server("stall", cfg);
    let addr = server.local_addr();
    fault::arm(FaultPlan { queue_stall_ms: 300, ..FaultPlan::default() });

    let good: Vec<f32> = vec![0.5; D_IN];
    let mut wire = Vec::new();
    for i in 0..4 {
        wire.extend_from_slice(&raw_predict_request(&format!("stall-{i}"), &good));
    }
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    stream.write_all(&wire).unwrap();

    // While the worker stalls the queue stays full, so the loop must
    // park this socket: the suspension gauge rises. `/metrics` is served
    // inline by the event loop, so it stays reachable throughout.
    let mut saw_suspended = false;
    let t0 = Instant::now();
    while t0.elapsed() < Duration::from_secs(10) {
        let (status, m) = http::client::request(addr, "GET", "/metrics", None).unwrap();
        assert_eq!(status, 200);
        let mj = json::parse(&m).unwrap();
        if mj.get("suspended_connections").as_usize().unwrap_or(0) >= 1 {
            saw_suspended = true;
            break;
        }
        thread::sleep(Duration::from_millis(5));
    }
    assert!(saw_suspended, "queue stall never suspended the flooding connection");
    fault::disarm();

    // Responses arrive strictly in pipeline order; under the stall at
    // least one of the four was shed with a 503, and the first (which
    // reached the queue before it filled) was served.
    let mut reader = BufReader::new(stream);
    let mut statuses = Vec::new();
    for i in 0..4 {
        let (status, headers, body) =
            read_raw_response(&mut reader).unwrap_or_else(|| panic!("missing response {i}"));
        assert_eq!(
            header_value(&headers, "x-request-id"),
            format!("stall-{i}"),
            "responses out of order: {body}"
        );
        assert!(
            status == 200 || status == 503,
            "response {i}: unexpected status {status}: {body}"
        );
        if status == 503 {
            let v = json::parse(&body).unwrap();
            assert_eq!(v.get("code").as_str(), Some("queue_full"), "{v}");
        }
        statuses.push(status);
    }
    assert_eq!(statuses[0], 200, "first pipelined request must be served: {statuses:?}");
    assert!(
        statuses.iter().any(|&s| s == 503),
        "no request was shed while the queue was stalled: {statuses:?}"
    );

    // Once the stall is gone and the pipeline is drained, the gauge
    // must return to zero (the socket resumed reading).
    let t0 = Instant::now();
    loop {
        let (_, m) = http::client::request(addr, "GET", "/metrics", None).unwrap();
        let mj = json::parse(&m).unwrap();
        if mj.get("suspended_connections").as_usize() == Some(0) {
            break;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "suspension never cleared after drain: {mj}"
        );
        thread::sleep(Duration::from_millis(10));
    }

    server.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn shutdown_is_graceful() {
    let (server, dir) = start_server("shutdown", ServeConfig::default());
    let addr = server.local_addr();
    let good: Vec<f32> = vec![0.25; D_IN];
    let (status, _) = post_predict(addr, &predict_body("served", &good));
    assert_eq!(status, 200);

    server.shutdown();
    // after shutdown the port no longer serves predictions
    let refused = http::client::request(addr, "POST", "/predict",
                                        Some(&predict_body("served", &good)));
    match refused {
        Err(_) => {}                          // connection refused — ideal
        Ok((status, _)) => assert_ne!(status, 200, "served after shutdown"),
    }
    std::fs::remove_dir_all(&dir).ok();
}
