//! Cross-engine equivalence matrix (DESIGN.md §11): one generated table
//! asserting whole-bundle forward outputs across every compute engine ×
//! popcount kernel × thread count, replacing the ad-hoc per-PR pairings
//! that used to live in `tests/bitslice.rs` / `tests/observe.rs`.
//!
//! Equivalence classes the matrix pins:
//!
//! * **binary class** — {BitPlane, Encrypted, mixed encrypted/bitplane
//!   policies} × {scalar, unrolled, avx2} × {1, 2, 4} threads are all
//!   **bit-identical**: the decrypt-on-demand engine fuses panel
//!   decryption into the tile loop but keeps the exact per-element
//!   accumulation order of the bit-plane GEMM, and output elements are
//!   independent of tile visit order and kernel choice.
//! * **dense class** — DenseF32 and the degenerate threshold policies
//!   (`bitplane@min=<huge>`, `encrypted@min=<huge>`) are bit-identical
//!   across 1/2/4 threads: a policy that assigns every layer dense must
//!   BE the dense engine, not an approximation of it.
//! * **tracing is an observer** — on every engine, forwards under
//!   trace=off / trace=all are bit-identical to untraced forwards.
//!
//! Plus the residency accounting the Encrypted engine exists to deliver:
//! a hand-computed `resident_bytes` check on the synthetic MLP fixture
//! and an HTTP acceptance run where an encrypted-mode ResNet serves
//! predictions in ≥99% top-1 agreement with dense while `GET /models`
//! reports lower resident bytes than the bit-plane entry.

use std::path::PathBuf;
use std::sync::Arc;

use flexor::coordinator::{export_synthetic_mlp_bundle, export_synthetic_resnet_bundle};
use flexor::inference::bitslice::popcount;
use flexor::inference::{ComputeMode, InferenceModel, ModePolicy};
use flexor::serve::{http, Registry, ServeConfig, Server};
use flexor::substrate::json::{self, Json};
use flexor::substrate::pool::ThreadPool;
use flexor::substrate::prng::Pcg32;
use flexor::substrate::trace;

fn bundle_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("flexor_engines_{tag}_{}", std::process::id()))
}

/// Exact bit pattern of a logit vector — `==` on `f32` would let
/// `-0.0 == 0.0` slip through the "bit-identical" claim.
fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|f| f.to_bits()).collect()
}

/// The binary-engine half of the matrix: every (engine, kernel, threads)
/// cell over one synthetic resnet bundle must produce the same bits.
/// (The kernel override is process-global; kernels are
/// exact-integer-identical, so a concurrent test observing a flipped
/// kernel still computes the same bits — the very property pinned here.)
#[test]
fn binary_engines_bit_identical_across_kernels_and_threads() {
    let dir = bundle_dir("matrix");
    export_synthetic_resnet_bundle(&dir, "r", 40, "resnet8", 8, 10).unwrap();
    const M: usize = 8;

    // the engine axis: both uniform binary engines plus mixed per-layer
    // policies that put different layers on different engines
    let models: Vec<(&str, InferenceModel)> = vec![
        (
            "bitplane",
            InferenceModel::load_with_mode(&dir, "r", ComputeMode::BitPlane { act_planes: M })
                .unwrap(),
        ),
        (
            "encrypted",
            InferenceModel::load_with_mode(&dir, "r", ComputeMode::Encrypted { act_planes: M })
                .unwrap(),
        ),
        (
            "mixed enc-base",
            InferenceModel::load_with_policy(
                &dir,
                "r",
                ModePolicy::parse(&format!("encrypted:{M},0=bitplane:{M}")).unwrap(),
            )
            .unwrap(),
        ),
        (
            "mixed bp-base",
            InferenceModel::load_with_policy(
                &dir,
                "r",
                ModePolicy::parse(&format!("bitplane:{M},0=encrypted:{M}")).unwrap(),
            )
            .unwrap(),
        ),
    ];
    assert_eq!(models[2].1.mode_label(), "mixed");
    assert_eq!(models[3].1.mode_label(), "mixed");
    // the encrypted entry never materializes decrypted planes, so its
    // quantized residency must undercut the bit-plane entry's
    assert!(
        models[1].1.quantized_resident_bytes() < models[0].1.quantized_resident_bytes(),
        "encrypted residency {} not below bitplane {}",
        models[1].1.quantized_resident_bytes(),
        models[0].1.quantized_resident_bytes()
    );

    let feat = 8 * 8 * 3;
    let mut rng = Pcg32::seeded(77);
    let x: Vec<f32> = (0..2 * feat).map(|_| rng.normal()).collect();
    let pools = [ThreadPool::new(1), ThreadPool::new(2), ThreadPool::new(4)];

    let mut first: Option<Vec<u32>> = None;
    let mut cells = 0usize;
    for kern in popcount::available() {
        assert!(popcount::set_override(Some(kern)), "{} refused", kern.label());
        for pool in &pools {
            for (label, model) in &models {
                let got = bits(&model.forward_with_pool(&x, 2, pool).unwrap());
                match &first {
                    None => first = Some(got),
                    Some(f) => assert_eq!(
                        *f,
                        got,
                        "cell ({label} × {} × {} threads) changed the bits",
                        kern.label(),
                        pool.threads()
                    ),
                }
                cells += 1;
            }
        }
    }
    popcount::set_override(None);
    // at least scalar × 3 pools × 4 engines even on the plainest host
    assert!(cells >= 12, "matrix ran only {cells} cells");
    std::fs::remove_dir_all(&dir).ok();
}

/// The dense half of the matrix: DenseF32 and the degenerate threshold
/// policies (every layer under `@min`) are the same engine, bit for bit,
/// across thread counts.
#[test]
fn dense_engine_identical_to_degenerate_policies() {
    let dir = bundle_dir("dense");
    export_synthetic_resnet_bundle(&dir, "r", 44, "resnet8", 8, 10).unwrap();

    let dense = InferenceModel::load(&dir, "r").unwrap();
    let models: Vec<(&str, InferenceModel)> = vec![
        (
            "bitplane@min=1000000",
            InferenceModel::load_with_policy(
                &dir,
                "r",
                ModePolicy::parse("bitplane@min=1000000").unwrap(),
            )
            .unwrap(),
        ),
        (
            "encrypted@min=1000000",
            InferenceModel::load_with_policy(
                &dir,
                "r",
                ModePolicy::parse("encrypted@min=1000000").unwrap(),
            )
            .unwrap(),
        ),
    ];
    for (label, m) in &models {
        assert_eq!(m.mode_label(), "dense", "{label} did not degenerate to dense");
    }

    let feat = 8 * 8 * 3;
    let mut rng = Pcg32::seeded(55);
    let x: Vec<f32> = (0..2 * feat).map(|_| rng.normal()).collect();
    let pools = [ThreadPool::new(1), ThreadPool::new(2), ThreadPool::new(4)];
    let want = bits(&dense.forward_with_pool(&x, 2, &pools[0]).unwrap());
    let table: Vec<(&str, &InferenceModel)> = std::iter::once(("dense", &dense))
        .chain(models.iter().map(|(l, m)| (*l, m)))
        .collect();
    for pool in &pools {
        for (label, m) in &table {
            let got = bits(&m.forward_with_pool(&x, 2, pool).unwrap());
            assert_eq!(
                want,
                got,
                "({label} × {} threads) diverged from the dense engine",
                pool.threads()
            );
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Trace state must only observe, never perturb — on **every** engine:
/// outputs are bit-identical with tracing off, sampled away, and fully
/// on. (Generalizes the old dense-only check from `tests/observe.rs`.)
#[test]
fn tracing_never_perturbs_any_engine() {
    let dir = bundle_dir("trace");
    export_synthetic_resnet_bundle(&dir, "r", 77, "resnet8", 8, 10).unwrap();
    let feat = 8 * 8 * 3;
    let mut rng = Pcg32::seeded(9);
    let x: Vec<f32> = (0..4 * feat).map(|_| rng.normal()).collect();

    for mode in [
        ComputeMode::DenseF32,
        ComputeMode::BitPlane { act_planes: 8 },
        ComputeMode::Encrypted { act_planes: 8 },
    ] {
        let model = InferenceModel::load_with_mode(&dir, "r", mode).unwrap();
        let baseline = model.forward(&x, 4).unwrap();
        let off = {
            let _t = trace::scope_with(trace::TraceMode::Off, None);
            model.forward(&x, 4).unwrap()
        };
        let profile = Arc::new(trace::Profile::new());
        let all = {
            let _t = trace::scope_with(trace::TraceMode::All, Some(profile.clone()));
            model.forward(&x, 4).unwrap()
        };
        assert!(
            profile.traced_forwards() >= 1,
            "{}: All-mode scope traced nothing",
            mode.label()
        );
        assert_eq!(bits(&baseline), bits(&off), "{}: trace=off changed results", mode.label());
        assert_eq!(bits(&baseline), bits(&all), "{}: trace=all changed results", mode.label());
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Satellite: `resident_bytes` accounting on the Encrypted engine,
/// hand-computed on the synthetic MLP fixture and re-asserted through
/// `GET /models`. Fixture geometry (export_synthetic_mlp_bundle):
/// q = 1, n_in = 8 encrypted bits → n_out = 10 decrypted bits per XOR
/// block, one quantized layer [16, 40] = 640 weights.
///
///   slices      = ceil(640 / 10)          = 64
///   enc columns = n_in = 8, each ceil(64/64) = 1 word → 8 × 8 = 64 B
///   M⊕ masks    = n_out × 4               = 40 B
///   parity      = n_out × 1               = 10 B
///   α           = c_out × 4 = 40 × 4      = 160 B
///   total       = 274 B  →  274·8 / 640   = 3.425 resident bits/weight
#[test]
fn encrypted_resident_bytes_hand_computed_on_mlp_fixture() {
    let dir = bundle_dir("resident");
    let d_in = 16usize;
    export_synthetic_mlp_bundle(&dir, "m", 51, d_in, &[40], 10).unwrap();
    const WANT_BYTES: usize = 64 + 40 + 10 + 160;
    const WANT_WEIGHTS: usize = 16 * 40;

    let enc = InferenceModel::load_with_mode(&dir, "m", ComputeMode::encrypted()).unwrap();
    assert_eq!(enc.quantized_resident_bytes(), WANT_BYTES, "encrypted resident bytes");
    let want_bpw = (WANT_BYTES * 8) as f64 / WANT_WEIGHTS as f64;
    assert!(
        (enc.resident_bits_per_weight() - want_bpw).abs() < 1e-12,
        "resident_bits_per_weight {} != {want_bpw}",
        enc.resident_bits_per_weight()
    );

    // the same layer held as decoded bit-planes costs more than its
    // encrypted form — the XOR-network overhead (masks + parity + α) is
    // already charged to the encrypted side above
    let bp = InferenceModel::load_with_mode(&dir, "m", ComputeMode::bit_plane()).unwrap();
    assert!(
        WANT_BYTES < bp.quantized_resident_bytes(),
        "encrypted {WANT_BYTES} B not below bitplane {} B",
        bp.quantized_resident_bytes()
    );

    // ...and the serving surface reports the same numbers
    let registry = Registry::with_default_mode(ComputeMode::encrypted());
    registry.load("m", &dir, "m").unwrap();
    let server = Server::start("127.0.0.1:0", registry, ServeConfig::default()).unwrap();
    let (status, body) =
        http::client::request(server.local_addr(), "GET", "/models", None).unwrap();
    assert_eq!(status, 200);
    let v = json::parse(&body).unwrap();
    let entry = &v.get("models").as_arr().unwrap()[0];
    assert_eq!(entry.get("compute_mode").as_str(), Some("encrypted"));
    assert_eq!(entry.get("quantized_weight_bytes").as_usize(), Some(WANT_BYTES));
    let served_bpw = entry.get("resident_bits_per_weight").as_f64().unwrap();
    assert!(
        (served_bpw - want_bpw).abs() < 1e-6,
        "GET /models resident_bits_per_weight {served_bpw} != {want_bpw}"
    );
    server.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// Acceptance: an encrypted-mode ResNet entry serves over HTTP in ≥ 99%
/// top-1 agreement with a dense entry of the same bundle, and
/// `GET /models` (not internal APIs) shows its resident quantized bytes
/// beating the bit-plane entry's.
#[test]
fn encrypted_serving_agrees_with_dense_and_beats_bitplane_residency() {
    let dir = bundle_dir("serve");
    export_synthetic_resnet_bundle(&dir, "rn", 33, "resnet8", 8, 10).unwrap();

    let registry = Registry::new();
    registry.load("dense", &dir, "rn").unwrap();
    registry
        .load_with_mode("bp", &dir, "rn", ComputeMode::BitPlane { act_planes: 24 })
        .unwrap();
    registry
        .load_with_mode("enc", &dir, "rn", ComputeMode::Encrypted { act_planes: 24 })
        .unwrap();
    let dense_entry = registry.get("dense").unwrap();
    let enc_entry = registry.get("enc").unwrap();

    // top-1 agreement over a procedural input set, batched through the
    // exact models the server holds
    const SAMPLES: usize = 100;
    let feat = 8 * 8 * 3;
    let mut rng = Pcg32::seeded(4242);
    let xs: Vec<f32> = (0..SAMPLES * feat).map(|_| rng.normal()).collect();
    let dense_preds = dense_entry.model.predict(&xs, SAMPLES).unwrap();
    let enc_preds = enc_entry.model.predict(&xs, SAMPLES).unwrap();
    let agree = dense_preds.iter().zip(&enc_preds).filter(|(a, b)| a == b).count();
    assert!(
        agree * 100 >= SAMPLES * 99,
        "top-1 agreement {agree}/{SAMPLES} below 99%"
    );

    let server = Server::start(
        "127.0.0.1:0",
        registry,
        ServeConfig { workers: 1, intra_threads: 1, ..ServeConfig::default() },
    )
    .unwrap();
    let addr = server.local_addr();

    // the HTTP path matches direct inference on the encrypted entry
    for i in 0..4 {
        let body = Json::obj(vec![
            ("model", Json::str("enc")),
            ("features",
             Json::arr(xs[i * feat..(i + 1) * feat].iter().map(|&v| Json::num(v)))),
        ])
        .to_string();
        let (status, resp) =
            http::client::request(addr, "POST", "/predict", Some(&body)).unwrap();
        assert_eq!(status, 200, "enc request {i}: {resp}");
        let pred = json::parse(&resp).unwrap().get("prediction").as_i64().unwrap();
        assert_eq!(pred as i32, enc_preds[i], "enc request {i} diverged");
    }

    // the residency claim is asserted off the serving surface
    let (status, body) = http::client::request(addr, "GET", "/models", None).unwrap();
    assert_eq!(status, 200);
    let v = json::parse(&body).unwrap();
    let models = v.get("models").as_arr().unwrap();
    assert_eq!(models.len(), 3);
    let find = |name: &str| {
        models
            .iter()
            .find(|m| m.get("name").as_str() == Some(name))
            .unwrap_or_else(|| panic!("missing {name} in /models"))
    };
    let (dm, bm, em) = (find("dense"), find("bp"), find("enc"));
    assert_eq!(em.get("compute_mode").as_str(), Some("encrypted"));
    assert_eq!(bm.get("compute_mode").as_str(), Some("bitplane"));
    let enc_bytes = em.get("quantized_weight_bytes").as_usize().unwrap();
    let bp_bytes = bm.get("quantized_weight_bytes").as_usize().unwrap();
    let dense_bytes = dm.get("quantized_weight_bytes").as_usize().unwrap();
    assert!(
        enc_bytes < bp_bytes && bp_bytes < dense_bytes,
        "residency not ordered: enc {enc_bytes} / bp {bp_bytes} / dense {dense_bytes}"
    );
    let enc_bpw = em.get("resident_bits_per_weight").as_f64().unwrap();
    let bp_bpw = bm.get("resident_bits_per_weight").as_f64().unwrap();
    assert!(
        enc_bpw < bp_bpw,
        "enc {enc_bpw} bits/weight not below bitplane {bp_bpw}"
    );
    // FP residue is mode-independent
    assert_eq!(
        em.get("fp_weight_bytes").as_usize(),
        dm.get("fp_weight_bytes").as_usize()
    );

    server.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}
