//! Observability integration tests: per-model metrics isolation,
//! Prometheus exposition, the per-layer profile endpoint, request-id
//! round-tripping, and the tracing overhead accounting. (That trace
//! state never changes numeric results on any engine is pinned by the
//! matrix in `tests/engines.rs`.)

use std::net::SocketAddr;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use flexor::coordinator::{export_synthetic_mlp_bundle, export_synthetic_resnet_bundle};
use flexor::inference::InferenceModel;
use flexor::serve::{http, Registry, ServeConfig, Server};
use flexor::substrate::json::{self, Json};
use flexor::substrate::prng::Pcg32;
use flexor::substrate::trace;

const D_IN: usize = 16;

fn bundle_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("flexor_observe_{tag}_{}", std::process::id()))
}

fn predict_body(model: &str, features: &[f32]) -> String {
    Json::obj(vec![
        ("model", Json::str(model)),
        ("features", Json::arr(features.iter().map(|&v| Json::num(v)))),
    ])
    .to_string()
}

fn post_predict(addr: SocketAddr, body: &str) -> (u16, Json) {
    let (status, resp) = http::client::request(addr, "POST", "/predict", Some(body)).unwrap();
    (status, json::parse(&resp).unwrap())
}

/// Two models behind one server: their `/metrics` counters must stay
/// disjoint, in both the JSON snapshot and the Prometheus exposition.
#[test]
fn per_model_metrics_are_isolated() {
    let dir_a = bundle_dir("iso_a");
    let dir_b = bundle_dir("iso_b");
    export_synthetic_mlp_bundle(&dir_a, "alpha", 7, D_IN, &[32, 24], 10).unwrap();
    export_synthetic_mlp_bundle(&dir_b, "beta", 8, D_IN, &[24], 10).unwrap();
    let registry = Registry::new();
    registry.load("alpha", &dir_a, "alpha").unwrap();
    registry.load("beta", &dir_b, "beta").unwrap();
    let server = Server::start("127.0.0.1:0", registry, ServeConfig::default()).unwrap();
    let addr = server.local_addr();

    let x: Vec<f32> = vec![0.5; D_IN];
    for _ in 0..3 {
        let (status, v) = post_predict(addr, &predict_body("alpha", &x));
        assert_eq!(status, 200, "{v}");
    }
    let (status, v) = post_predict(addr, &predict_body("beta", &x));
    assert_eq!(status, 200, "{v}");

    let (status, m) = http::client::request(addr, "GET", "/metrics", None).unwrap();
    assert_eq!(status, 200);
    let mj = json::parse(&m).unwrap();
    assert_eq!(mj.get("requests_total").as_usize(), Some(4));
    let models = mj.get("models");
    assert_eq!(models.get("alpha").get("requests_total").as_usize(), Some(3));
    assert_eq!(models.get("alpha").get("errors_total").as_usize(), Some(0));
    assert_eq!(models.get("beta").get("requests_total").as_usize(), Some(1));
    assert_eq!(models.get("beta").get("examples_total").as_usize(), Some(1));
    assert!(models.get("alpha").get("latency_ms").get("p99").as_f64().unwrap() >= 0.0);

    let (status, prom) =
        http::client::request(addr, "GET", "/metrics?format=prometheus", None).unwrap();
    assert_eq!(status, 200);
    assert!(prom.contains("flexor_model_requests_total{model=\"alpha\"} 3"), "{prom}");
    assert!(prom.contains("flexor_model_requests_total{model=\"beta\"} 1"), "{prom}");
    assert!(prom.contains("flexor_requests_total 4"), "{prom}");

    server.shutdown();
    std::fs::remove_dir_all(&dir_a).ok();
    std::fs::remove_dir_all(&dir_b).ok();
}

/// A metric name: `[a-zA-Z_:][a-zA-Z0-9_:]*`.
fn valid_metric_name(s: &str) -> bool {
    let mut chars = s.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' || c == ':' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

/// Line-level check of the text exposition format 0.0.4: every
/// non-comment line is `name[{labels}] value`, every sample belongs to
/// a family announced by `# TYPE`, and `# HELP` pairs with `# TYPE`.
#[test]
fn prometheus_exposition_is_parseable() {
    let dir = bundle_dir("prom");
    export_synthetic_mlp_bundle(&dir, "served", 7, D_IN, &[32], 10).unwrap();
    let registry = Registry::new();
    registry.load("served", &dir, "served").unwrap();
    let server = Server::start("127.0.0.1:0", registry, ServeConfig::default()).unwrap();
    let addr = server.local_addr();

    let x: Vec<f32> = vec![0.25; D_IN];
    let (status, _) = post_predict(addr, &predict_body("served", &x));
    assert_eq!(status, 200);

    let (status, headers, body) = http::client::request_with_headers(
        addr,
        "GET",
        "/metrics?format=prometheus",
        &[],
        None,
    )
    .unwrap();
    assert_eq!(status, 200);
    let ct = headers.iter().find(|(k, _)| k == "content-type").map(|(_, v)| v.as_str());
    assert_eq!(ct, Some("text/plain; version=0.0.4"));

    let mut typed: Vec<String> = Vec::new();
    let mut helped: Vec<String> = Vec::new();
    let mut samples = 0usize;
    for line in body.lines() {
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# HELP ") {
            helped.push(rest.split_whitespace().next().unwrap().to_string());
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            typed.push(it.next().unwrap().to_string());
            let kind = it.next().unwrap();
            assert!(
                ["counter", "gauge", "summary"].contains(&kind),
                "unknown metric type in {line:?}"
            );
            continue;
        }
        assert!(!line.starts_with('#'), "malformed comment line {line:?}");
        // sample: name[{labels}] value
        let (name_labels, value) = line.rsplit_once(' ').expect("sample has a value");
        assert!(value.parse::<f64>().is_ok(), "non-numeric value in {line:?}");
        let name = match name_labels.split_once('{') {
            Some((n, labels)) => {
                assert!(labels.ends_with('}'), "unterminated labels in {line:?}");
                n
            }
            None => name_labels,
        };
        assert!(valid_metric_name(name), "bad metric name in {line:?}");
        let family = name
            .strip_suffix("_sum")
            .or_else(|| name.strip_suffix("_count"))
            .unwrap_or(name);
        assert!(
            typed.iter().any(|t| t == family || t == name),
            "sample {name} has no # TYPE header"
        );
        samples += 1;
    }
    assert!(samples >= 10, "suspiciously few samples: {samples}");
    assert_eq!(typed, helped, "every family needs matching HELP and TYPE");
    for want in [
        "flexor_requests_total",
        "flexor_request_latency_ms",
        "flexor_queue_depth",
        "flexor_pool_threads",
        "flexor_trace_mode",
    ] {
        assert!(typed.iter().any(|t| t == want), "missing family {want}");
    }

    server.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// With tracing forced on, `/models/<name>/profile` reports per-layer
/// stage timing that accounts for the traced forwards.
#[test]
fn profile_endpoint_reports_stage_timing() {
    let dir = bundle_dir("profile");
    export_synthetic_mlp_bundle(&dir, "served", 7, D_IN, &[32, 24], 10).unwrap();
    let registry = Registry::new();
    registry.load("served", &dir, "served").unwrap();
    let cfg = ServeConfig { trace: Some(trace::TraceMode::All), ..ServeConfig::default() };
    let server = Server::start("127.0.0.1:0", registry, cfg).unwrap();
    let addr = server.local_addr();

    let x: Vec<f32> = vec![0.75; D_IN];
    for _ in 0..6 {
        let (status, v) = post_predict(addr, &predict_body("served", &x));
        assert_eq!(status, 200, "{v}");
    }

    let (status, body) =
        http::client::request(addr, "GET", "/models/served/profile", None).unwrap();
    assert_eq!(status, 200, "{body}");
    let p = json::parse(&body).unwrap();
    assert_eq!(p.get("model").as_str(), Some("served"));
    assert_eq!(p.get("trace_mode").as_str(), Some("all"));
    let forwards = p.get("traced_forwards").as_usize().unwrap();
    assert!((1..=6).contains(&forwards), "traced_forwards {forwards}");
    assert_eq!(p.get("forward").get("count").as_usize(), Some(forwards));
    let layers = p.get("layers").as_arr().unwrap();
    assert!(!layers.is_empty(), "no layers recorded: {body}");
    for layer in layers {
        assert!(!layer.get("layer").as_str().unwrap().is_empty());
        assert_eq!(layer.get("count").as_usize(), Some(forwards));
        for stage in layer.get("stages").as_arr().unwrap() {
            assert!(stage.get("count").as_usize().unwrap() > 0);
            assert!(stage.get("total_ms").as_f64().unwrap() >= 0.0);
            assert!(stage.get("mean_us").as_f64().unwrap() >= 0.0);
        }
    }

    let (status, _) = http::client::request(addr, "GET", "/models/ghost/profile", None).unwrap();
    assert_eq!(status, 404);

    server.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// The overhead contract's accounting half: per-layer span totals must
/// sum to (nearly) the end-to-end forward span — no stage double-counts
/// and no large untraced gap. The bench records the latency half
/// (`overhead_trace_sampled_vs_off`).
#[test]
fn profile_stage_sums_track_forward_latency() {
    let dir = bundle_dir("sums");
    export_synthetic_resnet_bundle(&dir, "r", 31, "resnet8", 8, 10).unwrap();
    let model = InferenceModel::load(&dir, "r").unwrap();
    let feat = 8 * 8 * 3;
    let mut rng = Pcg32::seeded(5);
    let x: Vec<f32> = (0..8 * feat).map(|_| rng.normal()).collect();
    model.predict(&x, 8).unwrap(); // warm-up, untraced

    let profile = Arc::new(trace::Profile::new());
    const ITERS: usize = 4;
    let wall = Instant::now();
    for _ in 0..ITERS {
        let _t = trace::scope_with(trace::TraceMode::All, Some(profile.clone()));
        model.predict(&x, 8).unwrap();
    }
    let wall_ms = wall.elapsed().as_secs_f64() * 1e3;

    let rows = profile.rows();
    let forward_ms: f64 = rows
        .iter()
        .filter(|r| r.layer.is_empty() && r.stage == "forward")
        .map(|r| r.total_ns as f64 / 1e6)
        .sum();
    let layer_ms: f64 = rows
        .iter()
        .filter(|r| r.stage == "layer")
        .map(|r| r.total_ns as f64 / 1e6)
        .sum();
    assert_eq!(profile.traced_forwards(), ITERS as u64);
    assert!(forward_ms > 0.0, "forward span never recorded");
    // layer spans nest inside forward, so they can never exceed it
    // (small epsilon for clock granularity)...
    assert!(
        layer_ms <= forward_ms * 1.05,
        "layer sum {layer_ms:.3}ms exceeds forward {forward_ms:.3}ms"
    );
    // ...and the taxonomy covers the bulk of the forward; typically
    // > 90%, asserted loosely so scheduler noise can't flake CI.
    assert!(
        layer_ms >= forward_ms * 0.5,
        "layer sum {layer_ms:.3}ms covers too little of forward {forward_ms:.3}ms"
    );
    // the forward span lives inside predict(), inside the walled loop
    assert!(
        forward_ms <= wall_ms,
        "forward {forward_ms:.3}ms exceeds wall {wall_ms:.3}ms"
    );
}

/// Request ids round-trip end to end: a client-supplied id is echoed in
/// the response header and body; a server-generated id appears on
/// errors too, so log lines can be joined to responses.
#[test]
fn request_ids_round_trip_end_to_end() {
    let dir = bundle_dir("rid");
    export_synthetic_mlp_bundle(&dir, "served", 7, D_IN, &[24], 10).unwrap();
    let registry = Registry::new();
    registry.load("served", &dir, "served").unwrap();
    let server = Server::start("127.0.0.1:0", registry, ServeConfig::default()).unwrap();
    let addr = server.local_addr();

    let x: Vec<f32> = vec![0.1; D_IN];
    let (status, headers, body) = http::client::request_with_headers(
        addr,
        "POST",
        "/predict",
        &[("X-Request-Id", "it-42.A")],
        Some(&predict_body("served", &x)),
    )
    .unwrap();
    assert_eq!(status, 200, "{body}");
    let echoed = headers.iter().find(|(k, _)| k == "x-request-id").map(|(_, v)| v.as_str());
    assert_eq!(echoed, Some("it-42.A"));
    assert_eq!(json::parse(&body).unwrap().get("request_id").as_str(), Some("it-42.A"));

    // no client id: the server mints one and it matches header ↔ body
    let (status, headers, body) = http::client::request_with_headers(
        addr,
        "POST",
        "/predict",
        &[],
        Some("{not json"),
    )
    .unwrap();
    assert_eq!(status, 400);
    let minted = headers
        .iter()
        .find(|(k, _)| k == "x-request-id")
        .map(|(_, v)| v.clone())
        .expect("error responses carry a request id");
    assert!(!minted.is_empty());
    assert_eq!(json::parse(&body).unwrap().get("request_id").as_str(), Some(minted.as_str()));

    server.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}
