//! Zero-allocation guarantee for the hot `/predict` parse path
//! (DESIGN.md §14): once a connection's buffers are warm, framing a
//! request ([`FrameParser`]) and stream-lexing its body into a recycled
//! feature buffer ([`Lexer`] + [`PredictVisitor`]) must perform **zero**
//! heap allocations per request. This is the property that lets the
//! event loop serve steady-state traffic without touching the allocator.
//!
//! The test installs a counting `#[global_allocator]`, so it lives in
//! its own integration-test binary: it is the only `#[test]` here, which
//! keeps other tests' allocations out of the measured window.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use flexor::serve::http::{FrameParser, PredictVisitor};
use flexor::substrate::json::Lexer;

struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// One full parse cycle: feed the raw request, frame it, lex the body
/// into the recycled feature buffer, hand the buffer back. Exactly what
/// the event loop does per request on a warm connection.
fn cycle(parser: &mut FrameParser, lexer: &mut Lexer, features: Vec<f32>, raw: &[u8]) -> Vec<f32> {
    parser.feed(raw);
    let frame = parser
        .next_frame()
        .expect("frame rejected")
        .expect("frame incomplete");
    let mut v = PredictVisitor::new(features);
    lexer.lex(frame.body, &mut v).expect("body rejected");
    assert_eq!(v.model(), Some("steady"), "model extraction changed");
    assert!(v.features_ok(), "features extraction changed");
    assert_eq!(v.features.len(), 12);
    let mut features = v.into_features();
    features.clear();
    parser.consume();
    features
}

#[test]
fn predict_parse_path_is_allocation_free_at_steady_state() {
    let body = r#"{"model":"steady","features":[1,2.5,-3e-2,4,5.5,6,7,8e0,9,10,11.25,12]}"#;
    let raw = format!(
        "POST /predict HTTP/1.1\r\nHost: x\r\nX-Request-Id: warm-1\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n{}",
        body.len(),
        body
    );

    let mut parser = FrameParser::new(8 << 20);
    let mut lexer = Lexer::new();
    let mut features: Vec<f32> = Vec::new();

    // Warm-up: let every reusable buffer (parser buf, lexer stack +
    // scratch, feature vec) reach its steady-state capacity.
    for _ in 0..32 {
        features = cycle(&mut parser, &mut lexer, features, raw.as_bytes());
    }

    let before = ALLOC_CALLS.load(Ordering::SeqCst);
    for _ in 0..256 {
        features = cycle(&mut parser, &mut lexer, features, raw.as_bytes());
    }
    let after = ALLOC_CALLS.load(Ordering::SeqCst);

    assert_eq!(
        after - before,
        0,
        "steady-state /predict parse path allocated {} times over 256 requests",
        after - before
    );
    assert!(features.is_empty());
}
