//! Offline stand-in for the `anyhow` crate (DESIGN.md §5: the build image
//! has no crates.io access, so third-party surface is vendored in-tree).
//!
//! Implements exactly the subset this workspace uses — `Result`, a
//! context-chaining `Error`, the `Context` extension trait for
//! `Result`/`Option`, and the `anyhow!` / `bail!` / `ensure!` macros —
//! with anyhow's rendering conventions: `{}` prints the outermost message,
//! `{:#}` the full `outer: inner: ...` chain, and `{:?}` a "Caused by:"
//! listing.

use std::fmt;

/// `std::result::Result` defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamic error: an outermost message plus the chain of causes
/// (outermost first). Deliberately does **not** implement
/// `std::error::Error`, which is what makes the blanket `From`/`Context`
/// impls below coherent — the same trick the real anyhow uses.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// Messages outermost → innermost.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost message (anyhow's `root_cause` analogue).
    pub fn root_cause(&self) -> &str {
        self.chain.last().expect("error chain is never empty")
    }

    fn from_std<E: std::error::Error + ?Sized>(e: &E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for c in &self.chain[1..] {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

// Coherent with core's reflexive `From<T> for T` because `Error` is a local
// type that does not (and, per the orphan rule, cannot downstream) implement
// `std::error::Error`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::from_std(&e)
    }
}

mod ext {
    use super::Error;

    /// Private unification of "things that can become an [`Error`]" —
    /// std errors (capturing their source chain) and `Error` itself.
    pub trait IntoError: Send + Sync + 'static {
        fn into_error(self) -> Error;
    }

    impl<E: std::error::Error + Send + Sync + 'static> IntoError for E {
        fn into_error(self) -> Error {
            Error::from_std(&self)
        }
    }

    impl IntoError for Error {
        fn into_error(self) -> Error {
            self
        }
    }
}

/// Attach context to `Result` errors / `None` options.
pub trait Context<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: ext::IntoError> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T, Error> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($fmt:literal, $($arg:tt)+) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)+))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Early-return with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)+))
    };
}

/// Early-return with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!(
                "condition failed: `{}`", ::std::stringify!($cond)
            ));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "disk on fire")
    }

    #[test]
    fn display_outermost_alternate_chain() {
        let e: Error = Error::from(io_err()).context("reading x");
        assert_eq!(format!("{e}"), "reading x");
        assert_eq!(format!("{e:#}"), "reading x: disk on fire");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("ctx").unwrap_err();
        assert_eq!(format!("{e:#}"), "ctx: disk on fire");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(format!("{e}"), "missing 7");
        assert_eq!(Some(3).context("unused").unwrap(), 3);
    }

    #[test]
    fn context_chains_on_anyhow_results() {
        fn inner() -> Result<()> {
            bail!("inner boom");
        }
        let e = inner().context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner boom");
        assert_eq!(e.root_cause(), "inner boom");
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            ensure!(x != 5);
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert_eq!(f(12).unwrap_err().to_string(), "x too big: 12");
        assert!(f(5).unwrap_err().to_string().contains("x != 5"));
        assert_eq!(f(3).unwrap_err().to_string(), "three is right out");
        let m = "msg";
        assert_eq!(anyhow!("{m}").to_string(), "msg");
        assert_eq!(anyhow!(String::from("owned")).to_string(), "owned");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<i32> {
            let n: i32 = "12x".parse()?;
            Ok(n)
        }
        assert!(f().unwrap_err().to_string().contains("invalid digit"));
    }
}
