//! Offline stand-in for the `xla` PJRT bindings (DESIGN.md §5).
//!
//! The build image used for CI has no XLA toolchain, so this crate provides
//! the API surface `runtime/` compiles against in two tiers:
//!
//! * **Host-side literals** ([`Literal`], [`ElementType`]) are fully
//!   functional — shape/dtype-checked byte buffers with the constructors
//!   and accessors the marshalling layer uses. Everything that only moves
//!   data (initbin parsing, checkpoint export, the serve subsystem) works.
//! * **PJRT execution** ([`PjRtClient`], compilation, `execute`) returns a
//!   descriptive [`Error`]: training/eval need the real `xla_extension`
//!   runtime. Integration tests and examples detect missing artifacts and
//!   skip before ever constructing a client, so `cargo test` passes on a
//!   fresh checkout.

use std::fmt;

/// Error type for all stubbed/validated operations.
#[derive(Clone, Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn err<T>(msg: &str) -> Result<T> {
    Err(Error(msg.to_string()))
}

const NO_RUNTIME: &str = "PJRT runtime unavailable: built against the vendored xla stub \
     (rust/vendor/xla). The pure-Rust decrypt/inference/serve paths work; \
     training and HLO execution need the real xla_extension toolchain";

/// Element dtypes the crate marshals (f32 tensors, i32 labels).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

/// Rust scalar types storable in a [`Literal`].
pub trait NativeType: Copy {
    const TY: ElementType;
    fn to_le(self) -> [u8; 4];
    fn from_le(b: [u8; 4]) -> Self;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn to_le(self) -> [u8; 4] {
        self.to_le_bytes()
    }
    fn from_le(b: [u8; 4]) -> Self {
        f32::from_le_bytes(b)
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn to_le(self) -> [u8; 4] {
        self.to_le_bytes()
    }
    fn from_le(b: [u8; 4]) -> Self {
        i32::from_le_bytes(b)
    }
}

/// A host-side typed, shaped byte buffer (array literal) or a tuple of
/// literals (the flat output convention of the AOT executables).
#[derive(Clone, Debug)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<usize>,
    bytes: Vec<u8>,
    tuple: Option<Vec<Literal>>,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        let mut bytes = Vec::with_capacity(v.len() * 4);
        for x in v {
            bytes.extend_from_slice(&x.to_le());
        }
        Literal { ty: T::TY, dims: vec![v.len()], bytes, tuple: None }
    }

    /// Rank-0 literal.
    pub fn scalar<T: NativeType>(x: T) -> Literal {
        Literal { ty: T::TY, dims: vec![], bytes: x.to_le().to_vec(), tuple: None }
    }

    /// Typed literal from raw little-endian bytes.
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let n: usize = dims.iter().product::<usize>().max(1);
        if data.len() != n * 4 {
            return err(&format!(
                "untyped data is {} bytes, shape {dims:?} needs {}",
                data.len(),
                n * 4
            ));
        }
        Ok(Literal { ty, dims: dims.to_vec(), bytes: data.to_vec(), tuple: None })
    }

    /// Wrap literals into a tuple literal.
    pub fn tuple(elems: Vec<Literal>) -> Literal {
        Literal { ty: ElementType::F32, dims: vec![], bytes: Vec::new(), tuple: Some(elems) }
    }

    /// Same bytes, new dims (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        if self.tuple.is_some() {
            return err("reshape of a tuple literal");
        }
        let new: Vec<usize> = dims.iter().map(|&d| d as usize).collect();
        let n_old: usize = self.dims.iter().product::<usize>().max(1);
        let n_new: usize = new.iter().product::<usize>().max(1);
        if n_old != n_new {
            return err(&format!("cannot reshape {:?} to {new:?}", self.dims));
        }
        Ok(Literal { ty: self.ty, dims: new, bytes: self.bytes.clone(), tuple: None })
    }

    pub fn element_type(&self) -> ElementType {
        self.ty
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// Full host copy, dtype-checked.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.tuple.is_some() {
            return err("to_vec of a tuple literal");
        }
        if self.ty != T::TY {
            return err(&format!("dtype mismatch: literal is {:?}", self.ty));
        }
        Ok(self
            .bytes
            .chunks_exact(4)
            .map(|c| T::from_le(c.try_into().expect("4-byte chunk")))
            .collect())
    }

    /// First element (scalar readback).
    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        let v = self.to_vec::<T>()?;
        match v.first() {
            Some(&x) => Ok(x),
            None => err("empty literal"),
        }
    }

    /// Flatten a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.tuple {
            Some(elems) => Ok(elems),
            None => err("not a tuple literal"),
        }
    }
}

/// Parsed HLO module (stub: parsing needs the real toolchain).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        err(NO_RUNTIME)
    }
}

/// Computation wrapper (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// PJRT client (stub: construction reports the missing runtime).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        err(NO_RUNTIME)
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        err(NO_RUNTIME)
    }
}

/// Compiled executable (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        err(NO_RUNTIME)
    }
}

/// Device buffer (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        err(NO_RUNTIME)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vec1_reshape_to_vec_roundtrip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(l.dims(), &[6]);
        let r = l.reshape(&[2, 3]).unwrap();
        assert_eq!(r.dims(), &[2, 3]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(l.reshape(&[4]).is_err());
    }

    #[test]
    fn scalar_and_dtype_checks() {
        let s = Literal::scalar(0.5f32);
        assert_eq!(s.get_first_element::<f32>().unwrap(), 0.5);
        assert!(s.to_vec::<i32>().is_err());
        let i = Literal::vec1(&[7i32, -1]);
        assert_eq!(i.to_vec::<i32>().unwrap(), vec![7, -1]);
        assert_eq!(i.element_type(), ElementType::S32);
    }

    #[test]
    fn untyped_data_validated() {
        let ok = Literal::create_from_shape_and_untyped_data(
            ElementType::F32,
            &[2],
            &[0u8; 8],
        );
        assert!(ok.is_ok());
        let bad = Literal::create_from_shape_and_untyped_data(
            ElementType::F32,
            &[2],
            &[0u8; 7],
        );
        assert!(bad.is_err());
    }

    #[test]
    fn tuple_roundtrip() {
        let t = Literal::tuple(vec![Literal::scalar(1.0f32), Literal::scalar(2i32)]);
        let elems = t.to_tuple().unwrap();
        assert_eq!(elems.len(), 2);
        assert!(Literal::scalar(0i32).to_tuple().is_err());
    }

    #[test]
    fn runtime_is_stubbed() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("stub"));
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
    }
}
