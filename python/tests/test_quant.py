"""Quantizers: FleXOR weight reconstruction, baselines (BWN / BinaryRelax /
ternary / DSQ), Quantizer dispatch and storage accounting."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import flexor, quant


KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# FlexorSpec / storage
# ---------------------------------------------------------------------------

def test_spec_bits_per_weight_and_storage():
    spec = quant.FlexorSpec(q=1, n_in=8, n_out=10, seed=1)
    assert spec.bits_per_weight == pytest.approx(0.8)
    # 100 weights -> 10 slices of 8 encrypted bits
    assert spec.storage_bits(100) == 80
    # padding: 101 weights -> 11 slices
    assert spec.storage_bits(101) == 88


def test_spec_q2_doubles_planes_and_storage():
    spec = quant.FlexorSpec(q=2, n_in=8, n_out=20, seed=1)
    assert len(spec.mxor) == 2
    assert (spec.mxor[0] != spec.mxor[1]).any()  # independent M⊕ per plane
    assert spec.bits_per_weight == pytest.approx(0.8)
    assert spec.storage_bits(100) == 2 * 5 * 8


def test_flexor_weight_shape_and_values():
    spec = quant.FlexorSpec(q=1, n_in=8, n_out=10, seed=2)
    shape = (3, 3, 4, 8)
    p = quant.init_flexor_weight(KEY, shape, spec)
    assert p["w_enc"].shape == (1, flexor.num_slices(int(np.prod(shape)), 10), 8)
    assert p["alpha"].shape == (1, 8)
    w = quant.flexor_weight(p, shape, spec, jnp.float32(10.0))
    assert w.shape == shape
    # q=1: every weight is ±α of its output channel
    alpha = np.asarray(p["alpha"][0])
    got = np.asarray(w)
    for oc in range(8):
        vals = np.unique(np.abs(got[..., oc]))
        np.testing.assert_allclose(vals, [alpha[oc]], rtol=1e-6)


def test_flexor_weight_q2_is_sum_of_planes():
    spec = quant.FlexorSpec(q=2, n_in=8, n_out=10, seed=3)
    shape = (16, 6)
    p = quant.init_flexor_weight(KEY, shape, spec)
    w = np.asarray(quant.flexor_weight(p, shape, spec, jnp.float32(10.0)))
    planes = []
    for i in range(2):
        bits = flexor.flexor_decrypt(p["w_enc"][i], jnp.float32(10.0),
                                     spec.mxor[i])
        flat = np.asarray(bits).reshape(-1)[:96].reshape(shape)
        planes.append(flat * np.asarray(p["alpha"][i])[None, :])
    np.testing.assert_allclose(w, planes[0] + planes[1], rtol=1e-6)


def test_flexor_weight_gradients_flow_to_enc_and_alpha():
    spec = quant.FlexorSpec(q=1, n_in=8, n_out=10, seed=4)
    shape = (16, 4)
    p = quant.init_flexor_weight(KEY, shape, spec)
    g = jax.grad(lambda pp: (quant.flexor_weight(
        pp, shape, spec, jnp.float32(10.0)) ** 2).sum())(p)
    assert float(jnp.abs(g["w_enc"]).sum()) > 0
    assert float(jnp.abs(g["alpha"]).sum()) > 0


def test_flexor_pallas_path_matches_jnp_path():
    spec = quant.FlexorSpec(q=1, n_in=8, n_out=10, seed=5)
    shape = (40, 5)
    p = quant.init_flexor_weight(KEY, shape, spec)
    w_jnp = quant.flexor_weight(p, shape, spec, jnp.float32(10.0))
    w_pal = quant.flexor_weight(p, shape, spec, jnp.float32(10.0),
                                use_pallas=True)
    np.testing.assert_allclose(np.asarray(w_jnp), np.asarray(w_pal),
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# Baselines
# ---------------------------------------------------------------------------

def test_bwn_weight_is_sign_times_channel_meanabs():
    p = quant.init_bwn_weight(KEY, (3, 3, 2, 4))
    w = np.asarray(p["w"])
    got = np.asarray(quant.bwn_weight(p))
    alpha = np.abs(w).reshape(-1, 4).mean(axis=0)
    np.testing.assert_allclose(got, np.sign(w) * alpha[None, None, None, :],
                               rtol=1e-6)


def test_bwn_gradient_clipped_ste():
    p = {"w": jnp.asarray([[0.5, -2.0], [0.9, 1.5]])}
    g = jax.grad(lambda pp: quant.bwn_weight(pp).sum())(p)["w"]
    # gradient through sign() is masked where |w| > 1 (clipped STE) but
    # alpha = mean|w| still contributes everywhere
    assert np.abs(np.asarray(g)).sum() > 0


def test_binaryrelax_limits():
    p = quant.init_binaryrelax_weight(KEY, (8, 3))
    w = np.asarray(p["w"])
    alpha = np.abs(w).mean(axis=0)
    # λ=0 → identity
    np.testing.assert_allclose(
        np.asarray(quant.binaryrelax_weight(p, jnp.float32(0.0))), w,
        rtol=1e-6)
    # λ→∞ → BWN-style sign·α
    got = np.asarray(quant.binaryrelax_weight(p, jnp.float32(1e9)))
    np.testing.assert_allclose(got, np.sign(w) * alpha[None, :], rtol=1e-4)


def test_ternary_zeros_small_weights_and_uses_trained_scales():
    p = quant.init_ternary_weight(KEY, (64, 2))
    w = np.asarray(p["w"])
    thr = 0.7 * np.abs(w).mean(axis=0)
    got = np.asarray(quant.ternary_weight(p))
    wp, wn = np.asarray(p["wp"]), np.asarray(p["wn"])
    for oc in range(2):
        np.testing.assert_allclose(got[w[:, oc] > thr[oc], oc], wp[oc])
        np.testing.assert_allclose(got[w[:, oc] < -thr[oc], oc], -wn[oc])
        mask = np.abs(w[:, oc]) <= thr[oc]
        np.testing.assert_allclose(got[mask, oc], 0.0)


def test_ternary_gradients_flow_to_w_and_scales():
    p = quant.init_ternary_weight(KEY, (64, 2))
    g = jax.grad(lambda pp: (quant.ternary_weight(pp) ** 2).sum())(p)
    assert float(jnp.abs(g["w"]).sum()) > 0
    assert float(jnp.abs(g["wp"]).sum()) > 0


def test_dsq_output_is_pm_alpha_and_trainable_k():
    p = quant.init_dsq_weight(KEY, (32, 3))
    got = np.asarray(quant.dsq_weight(p))
    alpha = np.abs(np.asarray(p["w"])).reshape(-1, 3).mean(axis=0)
    for oc in range(3):
        np.testing.assert_allclose(np.unique(np.abs(got[:, oc])), [alpha[oc]],
                                   rtol=1e-5)
    g = jax.grad(lambda pp: (quant.dsq_weight(pp) * 2).sum())(p)
    assert float(jnp.abs(g["w"]).sum()) > 0


# ---------------------------------------------------------------------------
# Quantizer dispatch
# ---------------------------------------------------------------------------

def test_quantizer_rejects_unknown_kind():
    with pytest.raises(ValueError):
        quant.Quantizer("nope")


def test_quantizer_flexor_requires_spec():
    with pytest.raises(ValueError):
        quant.Quantizer("flexor")


@pytest.mark.parametrize("kind", ["fp", "bwn", "binaryrelax", "ternary", "dsq"])
def test_quantizer_roundtrip_all_kinds(kind):
    qz = quant.Quantizer(kind)
    shape = (5, 5, 2, 6)
    p = qz.init(KEY, shape)
    ctx = {"s_tanh": jnp.float32(10.0), "relax_lambda": jnp.float32(2.0)}
    w = qz(p, shape, ctx)
    assert w.shape == shape


def test_quantizer_mixed_specs_route_by_layer():
    base = quant.FlexorSpec(q=1, n_in=12, n_out=20, seed=1)
    narrow = quant.FlexorSpec(q=1, n_in=8, n_out=20, seed=2)
    qz = quant.Quantizer("flexor", spec=base, specs={3: narrow})
    assert qz.spec_for(0) is base
    assert qz.spec_for(3) is narrow
    # bits/weight differ per group (Table 2)
    assert qz.storage_bits(1000, layer_idx=0) > qz.storage_bits(1000, layer_idx=3)


def test_quantizer_storage_bits_kinds():
    qz1 = quant.Quantizer("bwn")
    assert qz1.storage_bits(1000) == 1000
    assert quant.Quantizer("ternary").storage_bits(1000) == 2000
    assert quant.Quantizer("fp").storage_bits(10) == 320
    spec = quant.FlexorSpec(q=1, n_in=8, n_out=10, seed=1)
    assert quant.Quantizer("flexor", spec=spec).storage_bits(1000) == 800
