"""AOT compiler: config registry, HLO emission, init.bin format, manifest,
storage accounting, and incremental rebuild behaviour."""

import json
import struct
from pathlib import Path

import numpy as np
import pytest

from compile import aot, configs, quant


TINY = {
    "name": "tiny_test_cfg", "model": "mlp",
    "quantizer": {"kind": "flexor", "q": 1, "n_in": 4, "n_out": 5,
                  "n_tap": 2, "seed": 7},
    "batch": 8, "optimizer": "sgd", "weight_decay": 1e-5, "seed": 0,
    "in_hw": 28, "in_ch": 1, "num_classes": 4,
    "model_kwargs": {"d_in": 16, "hidden": [8]}, "tags": ["test"],
}


# ---------------------------------------------------------------------------
# config registry
# ---------------------------------------------------------------------------

def test_registry_default_set_small():
    d = configs.select("default")
    assert 3 <= len(d) <= 8
    names = {c["name"] for c in d}
    assert "quickstart_mlp" in names
    assert "e2e_resnet14_f08" in names


def test_registry_tags_cover_all_tables_and_figures():
    tags = set()
    for c in configs.REGISTRY.values():
        tags.update(c["tags"])
    for need in ["fig4", "fig5", "fig7", "fig8", "fig12", "fig16",
                 "table1", "table2", "table3", "table5", "table6", "table7"]:
        assert need in tags, f"no configs tagged {need}"


def test_registry_select_only_and_unknown():
    got = configs.select(only=["quickstart_mlp"])
    assert len(got) == 1
    with pytest.raises(KeyError):
        configs.select(only=["nope"])


def test_registry_bits_per_weight_sanity():
    """Named sweep configs encode their rate in the name."""
    c = configs.REGISTRY["sweep_q1_ni8_no20"]
    q = c["quantizer"]
    assert q["q"] * q["n_in"] / q["n_out"] == pytest.approx(0.4)
    c = configs.REGISTRY["sweep_q2_ni8_no20"]
    q = c["quantizer"]
    assert q["q"] * q["n_in"] / q["n_out"] == pytest.approx(0.8)


# ---------------------------------------------------------------------------
# quantizer factory
# ---------------------------------------------------------------------------

def test_make_quantizer_flexor_with_groups():
    qz = aot.make_quantizer({
        "kind": "flexor", "q": 1, "n_in": 12, "n_out": 20, "n_tap": 2,
        "seed": 7, "groups": [{"layers": [0, 1], "n_in": 19},
                              {"layers": [5], "n_in": 7}]})
    assert qz.spec_for(0).n_in == 19
    assert qz.spec_for(5).n_in == 7
    assert qz.spec_for(3).n_in == 12
    # group M⊕ seeds differ from the default's
    assert (qz.spec_for(0).mxor[0].shape == (20, 19))


def test_make_quantizer_baselines():
    for kind in ["fp", "bwn", "binaryrelax", "ternary", "dsq"]:
        assert aot.make_quantizer({"kind": kind}).kind == kind


# ---------------------------------------------------------------------------
# build + artifact format
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    assert aot.build_config(TINY, out) is True
    aot.write_manifest(out)
    return out


def test_build_emits_all_files(built):
    d = built / "tiny_test_cfg"
    for f in ["train_step.hlo.txt", "eval_step.hlo.txt", "init.bin",
              "meta.json"]:
        assert (d / f).exists() and (d / f).stat().st_size > 0


def test_hlo_text_is_hlo(built):
    txt = (built / "tiny_test_cfg" / "train_step.hlo.txt").read_text()
    assert txt.startswith("HloModule")
    assert "ENTRY" in txt


def test_incremental_skip_and_force(built):
    assert aot.build_config(TINY, built) is False          # hash matches
    changed = dict(TINY, seed=1)
    assert aot.build_config(changed, built) is True        # hash differs
    aot.build_config(TINY, built, force=True)              # restore


def test_init_bin_roundtrip(built):
    raw = (built / "tiny_test_cfg" / "init.bin").read_bytes()
    assert raw[:4] == aot.MAGIC
    version, n = struct.unpack_from("<II", raw, 4)
    assert version == 1
    meta = json.loads((built / "tiny_test_cfg" / "meta.json").read_text())
    assert n == len(meta["leaves"])
    # walk every leaf and confirm shapes match meta
    off = 12
    for lm in meta["leaves"]:
        tag, rank, _pad = struct.unpack_from("<BBH", raw, off)
        off += 4
        dims = struct.unpack_from(f"<{rank}I", raw, off)
        off += 4 * rank
        assert list(dims) == lm["shape"]
        count = int(np.prod(dims)) if rank else 1
        off += 4 * count
    assert off == len(raw)


def test_meta_counts_and_io(built):
    meta = json.loads((built / "tiny_test_cfg" / "meta.json").read_text())
    c = meta["counts"]
    io = meta["train_io"]
    assert io["inputs"] == c["params"] + c["opt"] + c["bn"] + 5
    assert io["outputs"] == c["params"] + c["opt"] + c["bn"] + 2
    assert io["state_feedback"] == c["params"] + c["opt"] + c["bn"]
    assert meta["eval_io"]["outputs"] == 3


def test_meta_storage_accounting(built):
    meta = json.loads((built / "tiny_test_cfg" / "meta.json").read_text())
    st = meta["storage"]
    # mlp d_in=16 hidden 8: one quantized layer of 16*8=128 weights,
    # n_out=5 → 26 slices × 4 bits... per layer check:
    layer = st["layers"][0]
    assert layer["weights"] == 128
    slices = -(-128 // 5)
    assert layer["stored_bits"] == slices * 4
    assert st["bits_per_weight"] == pytest.approx(slices * 4 / 128)


def test_meta_flexor_mxor_serialized(built):
    meta = json.loads((built / "tiny_test_cfg" / "meta.json").read_text())
    fx = meta["flexor"]["default"]
    m = np.asarray(fx["mxor"][0])
    assert m.shape == (5, 4)
    assert ((m == 0) | (m == 1)).all()
    assert (m.sum(axis=1) == 2).all()  # n_tap=2


def test_manifest_lists_config(built):
    man = json.loads((built / "manifest.json").read_text())
    assert "tiny_test_cfg" in man["configs"]
    e = man["configs"]["tiny_test_cfg"]
    assert e["model"] == "mlp"
    assert e["quantizer"] == "flexor"
