"""nn.py substrate: BN math vs manual, conv/pool geometry, dense, init."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import nn

KEY = jax.random.PRNGKey(0)


def test_he_normal_scale():
    w = nn.he_normal(KEY, (5, 5, 16, 32))
    std = float(jnp.std(w))
    want = (2.0 / (5 * 5 * 16)) ** 0.5
    assert abs(std - want) / want < 0.15


def test_relu():
    x = jnp.asarray([-1.0, 0.0, 2.0])
    np.testing.assert_array_equal(np.asarray(nn.relu(x)), [0.0, 0.0, 2.0])


def test_conv2d_identity_and_shapes():
    x = jax.random.normal(KEY, (2, 8, 8, 3))
    w_id = jnp.zeros((1, 1, 3, 3)).at[0, 0].set(jnp.eye(3))
    y = nn.conv2d(x, w_id)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), rtol=1e-6)
    w = jax.random.normal(KEY, (3, 3, 3, 7))
    assert nn.conv2d(x, w, stride=2).shape == (2, 4, 4, 7)
    assert nn.conv2d(x, w, stride=1).shape == (2, 8, 8, 7)


def test_conv2d_same_padding_sums():
    x = jnp.ones((1, 4, 4, 1))
    w = jnp.ones((3, 3, 1, 1))
    y = np.asarray(nn.conv2d(x, w))[0, :, :, 0]
    assert y[0, 0] == 4.0   # corner sees 2x2
    assert y[1, 1] == 9.0   # interior sees 3x3


def test_max_pool():
    x = jnp.asarray([[1.0, 5.0], [3.0, 2.0]]).reshape(1, 2, 2, 1)
    y = nn.max_pool(x)
    assert y.shape == (1, 1, 1, 1)
    assert float(y[0, 0, 0, 0]) == 5.0


def test_avg_pool_global():
    x = jnp.arange(8, dtype=jnp.float32).reshape(1, 2, 2, 2)
    y = np.asarray(nn.avg_pool_global(x))
    np.testing.assert_allclose(y, [[(0 + 2 + 4 + 6) / 4, (1 + 3 + 5 + 7) / 4]])


def test_batch_norm_train_math():
    p, s = nn.init_bn(2)
    x = jax.random.normal(KEY, (64, 2)) * 3.0 + 1.0
    y, new_s = nn.batch_norm(p, s, x, train=True)
    # normalized output: ~zero mean, ~unit var per channel
    assert np.allclose(np.asarray(y).mean(axis=0), 0.0, atol=1e-4)
    assert np.allclose(np.asarray(y).var(axis=0), 1.0, atol=1e-2)
    # running stats moved toward batch stats with momentum 0.9
    bm = np.asarray(x.mean(axis=0))
    np.testing.assert_allclose(np.asarray(new_s["mean"]), 0.1 * bm, rtol=1e-5)


def test_batch_norm_eval_uses_running_stats():
    p, s = nn.init_bn(1)
    s = {"mean": jnp.asarray([2.0]), "var": jnp.asarray([4.0])}
    x = jnp.asarray([[4.0]])
    y, new_s = nn.batch_norm(p, s, x, train=False)
    assert float(y[0, 0]) == pytest.approx((4.0 - 2.0) / 2.0, rel=1e-3)
    assert new_s is s  # eval must not touch state


def test_batch_norm_scale_bias():
    p, s = nn.init_bn(1)
    p = {"scale": jnp.asarray([3.0]), "bias": jnp.asarray([-1.0])}
    s = {"mean": jnp.asarray([0.0]), "var": jnp.asarray([1.0])}
    y, _ = nn.batch_norm(p, s, jnp.asarray([[2.0]]), train=False)
    assert float(y[0, 0]) == pytest.approx(2.0 * 3.0 - 1.0, rel=1e-3)


def test_dense_fp():
    p = nn.init_dense_fp(KEY, 3, 2)
    assert p["w"].shape == (3, 2)
    x = jnp.asarray([[1.0, 0.0, 0.0]])
    y = nn.dense_fp(p, x)
    np.testing.assert_allclose(np.asarray(y)[0], np.asarray(p["w"])[0], rtol=1e-6)
