"""L1 Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes / M⊕ configurations / value distributions; every
kernel must match ref to float32 tolerance.  Kernels run interpret=True
(CPU PJRT cannot execute Mosaic custom-calls)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import flexor
from compile.kernels import ref, xor_decrypt, flexor_fwd, binary_matmul

SETTINGS = dict(max_examples=20, deadline=None)


def _signs(key, shape):
    return jnp.sign(jax.random.normal(key, shape) + 1e-9)


# ---------------------------------------------------------------------------
# xor_decrypt kernel
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(slices=st.integers(1, 1400), n_in=st.integers(2, 24),
       extra=st.integers(0, 12), n_tap=st.one_of(st.none(), st.integers(1, 2)),
       seed=st.integers(0, 2**31 - 1))
def test_xor_decrypt_matches_ref(slices, n_in, extra, n_tap, seed):
    n_out = n_in + extra
    if n_tap is not None:
        n_tap = min(n_tap, n_in)
    m = flexor.make_mxor(n_out, n_in, n_tap=n_tap, seed=seed)
    x = _signs(jax.random.PRNGKey(seed), (slices, n_in))
    got = xor_decrypt.xor_decrypt(x, m)
    want = ref.xor_decrypt_ref(x, m)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_xor_decrypt_nonmultiple_tile():
    m = flexor.make_mxor(10, 8, n_tap=2, seed=0)
    for slices in [1, 511, 512, 513, 1025]:
        x = _signs(jax.random.PRNGKey(slices), (slices, 8))
        got = xor_decrypt.xor_decrypt(x, m)
        assert got.shape == (slices, 10)
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(ref.xor_decrypt_ref(x, m)))


# ---------------------------------------------------------------------------
# flexor_fwd kernel (training decrypt, fwd + Eq.6 bwd)
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(slices=st.integers(1, 900), n_in=st.integers(2, 20),
       extra=st.integers(0, 8), seed=st.integers(0, 2**31 - 1),
       s_tanh=st.floats(0.5, 100.0))
def test_flexor_fwd_and_bwd_match_ref(slices, n_in, extra, seed, s_tanh):
    n_out = n_in + extra
    m = flexor.make_mxor(n_out, n_in, n_tap=min(2, n_in), seed=seed)
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (slices, n_in)) * 0.05
    g = jax.random.normal(jax.random.fold_in(key, 1), (slices, n_out))

    y, vjp = jax.vjp(lambda xx: flexor_fwd.decrypt_train(xx, s_tanh, m), x)
    np.testing.assert_array_equal(np.asarray(y),
                                  np.asarray(ref.flexor_fwd_ref(x, m)))
    (dx,) = vjp(g)
    want = ref.flexor_bwd_ref(x, jnp.float32(s_tanh), m, g)
    np.testing.assert_allclose(np.asarray(dx), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_flexor_fwd_matches_jnp_custom_vjp_end_to_end():
    """Pallas path and jnp path must produce identical losses & grads."""
    m = flexor.make_mxor(10, 8, n_tap=2, seed=4)
    x = jax.random.normal(jax.random.PRNGKey(0), (129, 8)) * 0.02

    def loss_pallas(xx):
        return (flexor_fwd.decrypt_train(xx, 10.0, m) ** 3).sum()

    def loss_jnp(xx):
        return (flexor.flexor_decrypt(xx, jnp.float32(10.0), m) ** 3).sum()

    lp, gp = jax.value_and_grad(loss_pallas)(x)
    lj, gj = jax.value_and_grad(loss_jnp)(x)
    np.testing.assert_allclose(float(lp), float(lj), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(gp), np.asarray(gj),
                               rtol=1e-5, atol=1e-6)


def test_flexor_fwd_ablation_modes_route_to_jnp():
    m = flexor.make_mxor(10, 8, n_tap=2, seed=5)
    x = jax.random.normal(jax.random.PRNGKey(1), (33, 8)) * 0.05
    for mode, grad in [("ste", "approx"), ("analog", "approx"),
                       ("flexor", "exact")]:
        got = flexor_fwd.decrypt_train(x, 10.0, m, mode=mode, grad=grad)
        want = flexor.flexor_decrypt(x, jnp.float32(10.0), m,
                                     mode=mode, grad=grad)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# binary_matmul kernel
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(n=st.integers(1, 300), v=st.integers(1, 96), c=st.integers(1, 300),
       q=st.integers(1, 3), seed=st.integers(0, 2**31 - 1))
def test_binary_matmul_matches_ref(n, v, c, q, seed):
    key = jax.random.PRNGKey(seed)
    a = jax.random.normal(key, (n, v))
    bits = _signs(jax.random.fold_in(key, 1), (q, v, c))
    alpha = jax.random.uniform(jax.random.fold_in(key, 2), (q, c), minval=0.05)
    got = binary_matmul.binary_matmul(a, bits, alpha)
    want = ref.binary_matmul_ref(a, bits, alpha)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_binary_matmul_equals_scaled_dense():
    """q=1: binary-code GEMM must equal a dense matmul with ±α weights."""
    key = jax.random.PRNGKey(7)
    a = jax.random.normal(key, (17, 31))
    bits = _signs(jax.random.fold_in(key, 1), (1, 31, 13))
    alpha = jax.random.uniform(jax.random.fold_in(key, 2), (1, 13), minval=0.1)
    dense_w = bits[0] * alpha[0][None, :]
    got = binary_matmul.binary_matmul(a, bits, alpha)
    np.testing.assert_allclose(np.asarray(got), np.asarray(a @ dense_w),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# fused decrypt+matmul reference consistency
# ---------------------------------------------------------------------------

def test_decrypt_matmul_ref_composes():
    m = flexor.make_mxor(10, 8, n_tap=2, seed=6)
    v, c, q = 24, 7, 2
    slices = flexor.num_slices(v * c, 10)
    key = jax.random.PRNGKey(3)
    xs = _signs(key, (q, slices, 8))
    a = jax.random.normal(jax.random.fold_in(key, 1), (5, v))
    alpha = jax.random.uniform(jax.random.fold_in(key, 2), (q, c), minval=0.1)
    fused = ref.decrypt_matmul_ref(a, xs, m, alpha, v, c)
    planes = [ref.xor_decrypt_ref(xs[i], m).reshape(-1)[: v * c].reshape(v, c)
              for i in range(q)]
    manual = sum(a @ planes[i] * alpha[i][None, :] for i in range(q))
    np.testing.assert_allclose(np.asarray(fused), np.asarray(manual),
                               rtol=1e-5, atol=1e-5)
