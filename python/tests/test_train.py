"""Training graph: losses, optimizer update rules vs hand math, and
loss-decreases smoke runs for FleXOR and every baseline quantizer."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import quant, train


# ---------------------------------------------------------------------------
# losses / metrics
# ---------------------------------------------------------------------------

def test_softmax_xent_matches_manual():
    logits = jnp.asarray([[2.0, 0.0, -1.0], [0.0, 1.0, 0.0]])
    labels = jnp.asarray([0, 2], dtype=jnp.int32)
    p = jax.nn.softmax(logits)
    want = -(np.log(p[0, 0]) + np.log(p[1, 2])) / 2
    got = float(train.softmax_xent(logits, labels))
    assert got == pytest.approx(float(want), rel=1e-6)


def test_accuracy_and_top5():
    logits = jnp.asarray([[5.0, 1, 2, 3, 4, 0], [0, 1, 2, 3, 4, 5.0]])
    labels = jnp.asarray([0, 0], dtype=jnp.int32)
    assert float(train.accuracy_count(logits, labels)) == 1.0
    # label 0 is in top-5 of row 0 (rank 1) and row 1 (rank 6 → no)
    assert float(train.topk_count(logits, labels, k=5)) == 1.0


# ---------------------------------------------------------------------------
# optimizer math
# ---------------------------------------------------------------------------

def test_sgd_momentum_weight_decay_math():
    params = {"a": jnp.asarray([1.0, -2.0])}
    opt = train.sgd_init(params)
    grads = {"a": jnp.asarray([0.5, 0.5])}
    lr, mom, wd = 0.1, 0.9, 0.01
    p1, o1 = train.sgd_update(params, opt, grads, lr, momentum=mom,
                              weight_decay=wd)
    v1 = 0.0 * mom + np.asarray(grads["a"]) + wd * np.asarray(params["a"])
    np.testing.assert_allclose(np.asarray(p1["a"]),
                               np.asarray(params["a"]) - lr * v1, rtol=1e-6)
    # second step accumulates momentum
    p2, _ = train.sgd_update(p1, o1, grads, lr, momentum=mom, weight_decay=wd)
    v2 = mom * v1 + np.asarray(grads["a"]) + wd * np.asarray(p1["a"])
    np.testing.assert_allclose(np.asarray(p2["a"]),
                               np.asarray(p1["a"]) - lr * v2, rtol=1e-6)


def test_adam_first_step_math():
    params = {"a": jnp.asarray([1.0])}
    opt = train.adam_init(params)
    grads = {"a": jnp.asarray([0.2])}
    p1, o1 = train.adam_update(params, opt, grads, 0.01)
    # bias-corrected first step ≈ -lr * sign(g)
    np.testing.assert_allclose(np.asarray(p1["a"]), [1.0 - 0.01], rtol=1e-4)
    assert float(o1["t"]) == 1.0


def test_optimizer_registry():
    assert set(train.OPTIMIZERS) == {"sgd", "adam"}


# ---------------------------------------------------------------------------
# end-to-end: loss decreases on a separable synthetic task
# ---------------------------------------------------------------------------

def _toy_task(n=256, d=32, k=4, seed=0):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (n, d))
    w = jax.random.normal(jax.random.fold_in(key, 1), (d, k))
    y = jnp.argmax(x @ w, axis=1).astype(jnp.int32)
    return x, y


def _run(kind, steps=40, lr=0.05, optimizer="sgd", use_pallas=False):
    spec = quant.FlexorSpec(q=1, n_in=8, n_out=10, seed=1)
    qz = quant.Quantizer(kind, spec=spec if kind == "flexor" else None,
                         use_pallas=use_pallas)
    init_fn, step, eval_step = train.build(
        "mlp", qz, optimizer=optimizer,
        model_kwargs={"d_in": 32, "hidden": (24,), "num_classes": 4})
    p, o, b = init_fn(0)
    x, y = _toy_task()
    jstep = jax.jit(step)
    first = last = None
    lam = 0.0
    for i in range(steps):
        lam = i / steps * 5.0  # BinaryRelax λ schedule
        p, o, b, loss, acc = jstep(p, o, b, x, y, lr, 10.0, lam)
        if first is None:
            first = float(loss)
        last = float(loss)
    l, c, c5 = jax.jit(eval_step)(p, b, x, y, 10.0, lam)
    return first, last, float(c) / x.shape[0]


@pytest.mark.parametrize("kind", ["fp", "flexor", "bwn", "binaryrelax",
                                  "ternary", "dsq"])
def test_loss_decreases_all_quantizers(kind):
    first, last, acc = _run(kind)
    assert last < first * 0.9, f"{kind}: {first} -> {last}"
    assert acc > 0.4


def test_flexor_pallas_train_path():
    first, last, acc = _run("flexor", steps=25, use_pallas=True)
    assert last < first


def test_adam_path():
    first, last, acc = _run("fp", steps=30, lr=1e-2, optimizer="adam")
    assert last < first * 0.8


def test_eval_uses_running_bn_stats():
    """eval_step must be deterministic given fixed params/bn (no batch stats)."""
    spec = quant.FlexorSpec(q=1, n_in=8, n_out=10, seed=1)
    qz = quant.Quantizer("flexor", spec=spec)
    init_fn, step, eval_step = train.build(
        "mlp", qz, model_kwargs={"d_in": 32, "hidden": (24,), "num_classes": 4})
    p, o, b = init_fn(0)
    x, y = _toy_task()
    l1 = eval_step(p, b, x[:64], y[:64], 10.0, 0.0)[0]
    l2 = eval_step(p, b, x[:64], y[:64], 10.0, 0.0)[0]
    assert float(l1) == float(l2)
