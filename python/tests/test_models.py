"""Model zoo: shapes, quantized-layer plans, BN state threading, and
quantizer-agnosticism for every registered architecture."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import quant
from compile import models as model_zoo

KEY = jax.random.PRNGKey(0)
CTX = {"s_tanh": jnp.float32(10.0), "relax_lambda": jnp.float32(1.0)}


def _spec():
    return quant.FlexorSpec(q=1, n_in=8, n_out=10, seed=1)


def test_registry_contents():
    for name in ["mlp", "lenet5", "resnet20", "resnet32", "resnet8",
                 "resnet14", "resnet18img", "resnet10img"]:
        assert model_zoo.get(name) is not None
    with pytest.raises(KeyError):
        model_zoo.get("vgg")


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def test_mlp_shapes_and_bn_state():
    qz = quant.Quantizer("flexor", spec=_spec())
    mk = dict(d_in=64, hidden=(32, 16), num_classes=5)
    params, state = model_zoo.mlp.init(KEY, qz, **mk)
    x = jax.random.normal(KEY, (7, 64))
    logits, new_state = model_zoo.mlp.apply(params, state, x, qz, CTX, True, **mk)
    assert logits.shape == (7, 5)
    # training BN must move running stats
    assert not np.allclose(np.asarray(new_state["bn"][0]["mean"]),
                           np.asarray(state["bn"][0]["mean"]))
    # eval mode must not
    _, st2 = model_zoo.mlp.apply(params, state, x, qz, CTX, False, **mk)
    np.testing.assert_array_equal(np.asarray(st2["bn"][0]["mean"]),
                                  np.asarray(state["bn"][0]["mean"]))


# ---------------------------------------------------------------------------
# LeNet-5
# ---------------------------------------------------------------------------

def test_lenet_paper_architecture_shapes():
    shapes = dict(model_zoo.lenet.quantized_layer_shapes())
    assert shapes[0] == (5, 5, 1, 32)
    assert shapes[1] == (5, 5, 32, 64)
    assert shapes[2] == (7 * 7 * 64, 512)
    assert shapes[3] == (512, 10)


def test_lenet_forward():
    qz = quant.Quantizer("flexor", spec=_spec())
    mk = dict(width_mult=0.25)
    params, state = model_zoo.lenet.init(KEY, qz, **mk)
    x = jax.random.normal(KEY, (4, 28, 28, 1))
    logits, _ = model_zoo.lenet.apply(params, state, x, qz, CTX, True, **mk)
    assert logits.shape == (4, 10)
    # accepts flat input too
    logits2, _ = model_zoo.lenet.apply(params, state,
                                       x.reshape(4, -1), qz, CTX, True, **mk)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(logits2),
                               rtol=1e-5)


# ---------------------------------------------------------------------------
# ResNet family
# ---------------------------------------------------------------------------

def test_resnet20_depth():
    """ResNet-20 = 6·3+2: 19 quantized convs + stem + head... specifically
    3 stages × 3 blocks × 2 convs = 18 3×3 convs, + 2 quantized 1×1
    downsamples = 20 quantized layers."""
    shapes = model_zoo.resnet.resnet20.quantized_layer_shapes()
    n3x3 = sum(1 for _, s in shapes if s[0] == 3)
    n1x1 = sum(1 for _, s in shapes if s[0] == 1)
    assert n3x3 == 18
    assert n1x1 == 2


def test_resnet32_depth():
    shapes = model_zoo.resnet.resnet32.quantized_layer_shapes()
    assert sum(1 for _, s in shapes if s[0] == 3) == 30


def test_resnet18img_plan():
    shapes = model_zoo.resnet.resnet18img.quantized_layer_shapes()
    n3x3 = sum(1 for _, s in shapes if s[0] == 3)
    n1x1 = sum(1 for _, s in shapes if s[0] == 1)
    assert n3x3 == 16  # 4 stages × 2 blocks × 2 convs
    assert n1x1 == 3   # downsample at stages 2,3,4


@pytest.mark.parametrize("name,hw,nc", [("resnet8", 32, 10),
                                        ("resnet10img", 64, 20)])
def test_resnet_forward_shapes(name, hw, nc):
    model = model_zoo.get(name)
    qz = quant.Quantizer("flexor", spec=_spec())
    params, state = model.init(KEY, qz)
    x = jax.random.normal(KEY, (2, hw, hw, 3))
    logits, new_state = model.apply(params, state, x, qz, CTX, True)
    assert logits.shape == (2, nc)
    assert len(new_state["bn"]) == len(state["bn"])
    assert all(s is not None for s in new_state["bn"])


@pytest.mark.parametrize("kind", ["fp", "bwn", "binaryrelax", "ternary", "dsq"])
def test_resnet8_quantizer_agnostic(kind):
    model = model_zoo.get("resnet8")
    qz = quant.Quantizer(kind)
    params, state = model.init(KEY, qz)
    x = jax.random.normal(KEY, (2, 32, 32, 3))
    logits, _ = model.apply(params, state, x, qz, CTX, True)
    assert logits.shape == (2, 10)
    assert np.isfinite(np.asarray(logits)).all()


def test_resnet_downsample_spatial_reduction():
    model = model_zoo.get("resnet8")
    qz = quant.Quantizer("fp")
    params, state = model.init(KEY, qz)
    # 32x32 input, three stages with strides 1,2,2 → final maps are 8×8
    x = jax.random.normal(KEY, (1, 32, 32, 3))
    logits, _ = model.apply(params, state, x, qz, CTX, False)
    assert np.isfinite(np.asarray(logits)).all()


def test_resnet_mixed_precision_specs_apply():
    """Table 2 setup: different N_in per layer group changes param shapes."""
    base = quant.FlexorSpec(q=1, n_in=12, n_out=20, seed=1)
    narrow = quant.FlexorSpec(q=1, n_in=7, n_out=20, seed=2)
    n_layers = len(model_zoo.resnet.resnet8.quantized_layer_shapes())
    qz = quant.Quantizer("flexor", spec=base,
                         specs={n_layers - 1: narrow})
    params, state = model_zoo.resnet.resnet8.init(KEY, qz)
    assert params["convs"][0]["w_enc"].shape[-1] == 12
    assert params["convs"][-1]["w_enc"].shape[-1] == 7
    x = jax.random.normal(KEY, (2, 32, 32, 3))
    logits, _ = model_zoo.resnet.resnet8.apply(params, state, x, qz, CTX, True)
    assert logits.shape == (2, 10)
