"""Core FleXOR math: M⊕ construction, Boolean decrypt semantics, and the
paper's custom gradients (Eq. 5/6, STE, analog) — each checked against
brute-force / analytic references."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import flexor


# ---------------------------------------------------------------------------
# M⊕ construction
# ---------------------------------------------------------------------------

def test_mxor_shape_and_binary():
    m = flexor.make_mxor(20, 8, n_tap=2, seed=0)
    assert m.shape == (20, 8)
    assert set(np.unique(m)) <= {0, 1}


def test_mxor_ntap_rows():
    for n_tap in [1, 2, 3, 5]:
        m = flexor.make_mxor(16, 8, n_tap=n_tap, seed=3)
        assert (m.sum(axis=1) == n_tap).all()


def test_mxor_random_rows_nonzero():
    m = flexor.make_mxor(64, 4, n_tap=None, seed=1)
    assert (m.sum(axis=1) >= 1).all()


def test_mxor_deterministic_by_seed():
    a = flexor.make_mxor(10, 8, n_tap=2, seed=42)
    b = flexor.make_mxor(10, 8, n_tap=2, seed=42)
    c = flexor.make_mxor(10, 8, n_tap=2, seed=43)
    assert (a == b).all()
    assert (a != c).any()


def test_mxor_rejects_expansion():
    with pytest.raises(ValueError):
        flexor.make_mxor(4, 8)


def test_mxor_rejects_bad_ntap():
    with pytest.raises(ValueError):
        flexor.make_mxor(10, 8, n_tap=9)
    with pytest.raises(ValueError):
        flexor.make_mxor(10, 8, n_tap=0)


def test_bits_per_weight():
    assert flexor.bits_per_weight(1, 8, 10) == pytest.approx(0.8)
    assert flexor.bits_per_weight(2, 8, 20) == pytest.approx(0.8)
    assert flexor.bits_per_weight(1, 8, 20) == pytest.approx(0.4)


def test_num_slices_ceil():
    assert flexor.num_slices(100, 10) == 10
    assert flexor.num_slices(101, 10) == 11
    assert flexor.num_slices(1, 10) == 1


# ---------------------------------------------------------------------------
# Boolean decrypt semantics vs bit-level brute force
# ---------------------------------------------------------------------------

def _bruteforce_decrypt(bits01, m):
    """Literal GF(2) y = M⊕ x over {0,1}, then map to ±1 with 0→-1.

    Paper's ±1 convention: stored bit b ∈ {0,1} maps to sign 2b-1, and the
    XOR-of-bits result r maps to 2r-1.
    """
    y = (m @ bits01.T % 2).T          # (slices, N_out) in {0,1}
    return 2.0 * y - 1.0


@pytest.mark.parametrize("n_out,n_in,n_tap", [(10, 8, 2), (20, 8, None),
                                              (10, 4, 3), (20, 16, 2)])
def test_decrypt_matches_gf2_bruteforce(n_out, n_in, n_tap):
    rng = np.random.default_rng(0)
    m = flexor.make_mxor(n_out, n_in, n_tap=n_tap, seed=5)
    bits01 = rng.integers(0, 2, size=(23, n_in)).astype(np.float32)
    x_sign = 2.0 * bits01 - 1.0
    got = np.asarray(flexor.decrypt_bits(jnp.asarray(x_sign), m))
    want = _bruteforce_decrypt(bits01, m)
    np.testing.assert_array_equal(got, want)


def test_decrypt_paper_appendix_example():
    """Appendix A's 6×4 M⊕, checked row by row against XOR arithmetic."""
    m = np.array([[1, 0, 1, 1],
                  [1, 1, 0, 0],
                  [1, 1, 1, 0],
                  [0, 0, 1, 1],
                  [0, 1, 0, 1],
                  [0, 1, 1, 1]], dtype=np.int8)
    for bits in range(16):
        b01 = np.array([(bits >> i) & 1 for i in range(4)], dtype=np.float32)
        x = (2 * b01 - 1)[None, :]
        y = np.asarray(flexor.decrypt_bits(jnp.asarray(x), m))[0]
        want01 = [
            b01[0] != b01[2] if False else (b01[0] + b01[2] + b01[3]) % 2,
            (b01[0] + b01[1]) % 2,
            (b01[0] + b01[1] + b01[2]) % 2,
            (b01[2] + b01[3]) % 2,
            (b01[1] + b01[3]) % 2,
            (b01[1] + b01[2] + b01[3]) % 2,
        ]
        np.testing.assert_array_equal(y, 2 * np.array(want01) - 1)


def test_decrypt_outputs_are_exactly_pm1():
    m = flexor.make_mxor(20, 12, n_tap=2, seed=9)
    x = jax.random.normal(jax.random.PRNGKey(0), (41, 12))
    y = np.asarray(flexor.flexor_decrypt(x, jnp.float32(10.0), m))
    assert set(np.unique(y)) <= {-1.0, 1.0}


def test_xor_truth_table_two_inputs():
    """Table 4 of the paper: F⊕(x1,x2) = -sign(x1)sign(x2)."""
    m = np.array([[1, 1]], dtype=np.int8)
    for s1 in (-1.0, 1.0):
        for s2 in (-1.0, 1.0):
            y = float(flexor.decrypt_bits(jnp.asarray([[s1, s2]]), m)[0, 0])
            assert y == -s1 * s2


# ---------------------------------------------------------------------------
# Hamming-distance analysis (paper §2)
# ---------------------------------------------------------------------------

def test_hamming_stats_distinct_rows():
    m = np.array([[1, 1, 0], [1, 1, 0], [0, 1, 1]], dtype=np.int8)
    st = flexor.hamming_distance_stats(m)
    assert st["total_row_pairs"] == 3
    assert st["distinct_row_pairs"] == 2
    assert st["mean_hamming"] == pytest.approx((0 + 4 + 4) / 3)


def test_hamming_stats_larger_nout_more_diversity():
    m10 = flexor.make_mxor(10, 8, n_tap=None, seed=0)
    m20 = flexor.make_mxor(20, 16, n_tap=None, seed=0)
    s10 = flexor.hamming_distance_stats(m10)
    s20 = flexor.hamming_distance_stats(m20)
    # larger N_in ⇒ pairwise distance 2^{N_in-1} grows (paper's argument)
    assert s20["mean_hamming"] > s10["mean_hamming"]


# ---------------------------------------------------------------------------
# Gradients
# ---------------------------------------------------------------------------

def _rand(n=17, n_in=8, seed=0):
    x = jax.random.normal(jax.random.PRNGKey(seed), (n, n_in)) * 0.05
    return x


def test_eq6_gradient_analytic():
    """Custom VJP must equal the hand-derived Eq. (6) formula."""
    m = flexor.make_mxor(10, 8, n_tap=2, seed=1)
    x = _rand()
    s = jnp.float32(10.0)
    g = jax.random.normal(jax.random.PRNGKey(1), (17, 10))
    got = jax.grad(lambda xx: (flexor.flexor_decrypt(xx, s, m) * g).sum())(x)

    y = np.asarray(flexor.flexor_decrypt(x, s, m))
    t = np.tanh(np.asarray(x) * 10.0)
    want = (np.asarray(g) * y) @ m.astype(np.float32) * 10.0 * (1 - t * t) \
        * np.sign(np.asarray(x))
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-6)


def test_eq5_exact_gradient_matches_tanh_autodiff():
    """Eq. (5) must equal autodiff through the pure tanh-product network."""
    m = flexor.make_mxor(6, 4, n_tap=2, seed=2)
    x = _rand(n=9, n_in=4, seed=3)
    s = jnp.float32(3.0)
    g = jax.random.normal(jax.random.PRNGKey(4), (9, 6))

    got = jax.grad(lambda xx: (flexor.flexor_decrypt(
        xx, s, m, grad="exact") * g).sum())(x)

    def analog_net(xx):
        t = jnp.tanh(xx * s)
        tb = jnp.where(jnp.asarray(m)[None] > 0, t[:, None, :], 1.0)
        full = jnp.prod(tb, axis=2)
        ntap = m.sum(axis=1)
        par = jnp.where((ntap - 1) % 2 == 0, 1.0, -1.0)
        return (par[None, :] * full * g).sum()

    want = jax.grad(analog_net)(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_ste_gradient():
    """STE mode: ∂y_r/∂x_i = y_r sign(x_i) summed through M⊕."""
    m = flexor.make_mxor(10, 8, n_tap=2, seed=3)
    x = _rand(seed=5)
    g = jax.random.normal(jax.random.PRNGKey(6), (17, 10))
    got = jax.grad(lambda xx: (flexor.flexor_decrypt(
        xx, jnp.float32(10.0), m, mode="ste") * g).sum())(x)
    y = np.asarray(flexor.flexor_decrypt(x, jnp.float32(10.0), m))
    want = (np.asarray(g) * y) @ m.astype(np.float32) * np.sign(np.asarray(x))
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-6)


def test_analog_mode_forward_binary_and_grad_flows():
    m = flexor.make_mxor(10, 8, n_tap=2, seed=4)
    x = _rand(seed=7)
    y = flexor.flexor_decrypt(x, jnp.float32(10.0), m, mode="analog")
    assert set(np.unique(np.asarray(y))) <= {-1.0, 1.0}
    g = jax.grad(lambda xx: flexor.flexor_decrypt(
        xx, jnp.float32(10.0), m, mode="analog").sum())(x)
    assert np.abs(np.asarray(g)).sum() > 0


def test_s_tanh_scales_gradient_magnitude():
    """Fig. 9: larger S_tanh ⇒ larger gradient for near-zero weights."""
    m = flexor.make_mxor(10, 8, n_tap=2, seed=5)
    x = _rand(seed=8) * 0.01
    def gnorm(s):
        g = jax.grad(lambda xx: flexor.flexor_decrypt(
            xx, jnp.float32(s), m).sum())(x)
        return float(jnp.abs(g).sum())
    assert gnorm(100.0) > gnorm(10.0) > gnorm(1.0)


def test_gradient_zero_for_saturated_weights():
    """(1 - tanh²) kills gradients for |x·S| >> 1 — the paper's built-in
    clipping ('eliminates the need for weight clipping')."""
    m = flexor.make_mxor(10, 8, n_tap=2, seed=6)
    x = jnp.ones((5, 8)) * 10.0
    g = jax.grad(lambda xx: flexor.flexor_decrypt(
        xx, jnp.float32(100.0), m).sum())(x)
    assert float(jnp.abs(g).max()) < 1e-12


def test_no_gradient_to_s_tanh():
    m = flexor.make_mxor(10, 8, n_tap=2, seed=7)
    x = _rand(seed=9)
    g = jax.grad(lambda s: flexor.flexor_decrypt(x, s, m).sum())(jnp.float32(10.0))
    assert float(g) == 0.0


def test_mode_validation():
    m = flexor.make_mxor(10, 8, n_tap=2, seed=8)
    with pytest.raises(ValueError):
        flexor.flexor_decrypt(jnp.zeros((2, 8)), jnp.float32(1.0), m,
                              mode="nope")
