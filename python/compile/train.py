"""L2 training graph: loss + grads + optimizer update as ONE jax function.

The whole step lowers to a single HLO artifact; the Rust coordinator (L3)
feeds parameters/optimizer-state/BN-state literals back in each step along
with the batch and the *scheduled scalars* (lr, s_tanh, relax_lambda), so
every schedule the paper uses (warmup, step decay, S_tanh doubling,
BinaryRelax λ growth) lives in Rust without re-lowering.

Optimizers are implemented here as pure pytree maps (SGD+momentum+weight
decay — the paper's CIFAR/ImageNet recipe; Adam — the paper's MNIST recipe)
so no external optimizer library is on the compile path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import models as model_zoo


# --- losses -------------------------------------------------------------------

def softmax_xent(logits, labels):
    """labels: int32 (N,).  Mean cross-entropy."""
    logz = jax.nn.log_softmax(logits)
    ll = jnp.take_along_axis(logz, labels[:, None], axis=1)[:, 0]
    return -ll.mean()


def accuracy_count(logits, labels):
    return (jnp.argmax(logits, axis=1) == labels).sum().astype(jnp.float32)


def topk_count(logits, labels, k: int = 5):
    # rank-based formulation: the label is in the top-k iff fewer than k
    # logits are strictly greater. (jax.lax.top_k lowers to a `topk` op
    # with a `largest=` attribute the xla_extension 0.5.1 HLO parser
    # rejects; this form lowers to plain compares/reductions.)
    k = min(k, logits.shape[1])
    label_logit = jnp.take_along_axis(logits, labels[:, None], axis=1)
    rank = (logits > label_logit).sum(axis=1)
    return (rank < k).sum().astype(jnp.float32)


# --- optimizers -----------------------------------------------------------------

def sgd_init(params):
    return {"mom": jax.tree.map(jnp.zeros_like, params)}


def sgd_update(params, opt, grads, lr, momentum: float = 0.9,
               weight_decay: float = 1e-5):
    def upd(p, v, g):
        v2 = momentum * v + g + weight_decay * p
        return p - lr * v2, v2
    flat_p, tdef = jax.tree.flatten(params)
    flat_v = tdef.flatten_up_to(opt["mom"])
    flat_g = tdef.flatten_up_to(grads)
    new = [upd(p, v, g) for p, v, g in zip(flat_p, flat_v, flat_g)]
    return (tdef.unflatten([a for a, _ in new]),
            {"mom": tdef.unflatten([b for _, b in new])})


def adam_init(params):
    return {"m": jax.tree.map(jnp.zeros_like, params),
            "v": jax.tree.map(jnp.zeros_like, params),
            "t": jnp.zeros((), jnp.float32)}


def adam_update(params, opt, grads, lr, b1: float = 0.9, b2: float = 0.999,
                eps: float = 1e-8, weight_decay: float = 0.0):
    t = opt["t"] + 1.0
    def upd(p, m, v, g):
        g = g + weight_decay * p
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        mh = m2 / (1 - b1 ** t)
        vh = v2 / (1 - b2 ** t)
        return p - lr * mh / (jnp.sqrt(vh) + eps), m2, v2
    flat_p, tdef = jax.tree.flatten(params)
    flat_m = tdef.flatten_up_to(opt["m"])
    flat_v = tdef.flatten_up_to(opt["v"])
    flat_g = tdef.flatten_up_to(grads)
    new = [upd(p, m, v, g) for p, m, v, g in zip(flat_p, flat_m, flat_v, flat_g)]
    return (tdef.unflatten([a for a, _, _ in new]),
            {"m": tdef.unflatten([b for _, b, _ in new]),
             "v": tdef.unflatten([c for _, _, c in new]),
             "t": t})


OPTIMIZERS = {
    "sgd": (sgd_init, sgd_update),
    "adam": (adam_init, adam_update),
}


# --- step builders ---------------------------------------------------------------

def build(model_name: str, qz, optimizer: str = "sgd",
          weight_decay: float = 1e-5, model_kwargs: dict | None = None):
    """Returns (init_fn, train_step, eval_step) closures for one config.

    init_fn(seed) -> (params, opt_state, bn_state)
    train_step(params, opt, bn, x, y, lr, s_tanh, relax_lambda)
        -> (params, opt, bn, loss, correct)
    eval_step(params, bn, x, y, s_tanh, relax_lambda)
        -> (loss, correct, top5_correct)
    """
    model = model_zoo.get(model_name)
    mk = model_kwargs or {}
    opt_init, opt_update = OPTIMIZERS[optimizer]

    def init_fn(seed: int):
        params, bn_state = model.init(jax.random.PRNGKey(seed), qz, **mk)
        return params, opt_init(params), bn_state

    def loss_fn(params, bn_state, x, y, ctx):
        logits, new_bn = model.apply(params, bn_state, x, qz, ctx, True, **mk)
        return softmax_xent(logits, y), (new_bn, logits)

    def train_step(params, opt, bn, x, y, lr, s_tanh, relax_lambda):
        ctx = {"s_tanh": s_tanh, "relax_lambda": relax_lambda}
        (loss, (new_bn, logits)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, bn, x, y, ctx)
        kw = {"weight_decay": weight_decay} if optimizer == "sgd" else {}
        new_params, new_opt = opt_update(params, opt, grads, lr, **kw)
        return new_params, new_opt, new_bn, loss, accuracy_count(logits, y)

    def eval_step(params, bn, x, y, s_tanh, relax_lambda):
        ctx = {"s_tanh": s_tanh, "relax_lambda": relax_lambda}
        logits, _ = model.apply(params, bn, x, qz, ctx, False, **mk)
        return (softmax_xent(logits, y), accuracy_count(logits, y),
                topk_count(logits, y, k=5))

    return init_fn, train_step, eval_step
