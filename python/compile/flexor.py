"""FleXOR core math: XOR-gate networks (M⊕) and the trainable decrypt.

Implements the paper's Section 2/3:

  * ``make_mxor`` — the fixed binary matrix M⊕ ∈ {0,1}^{N_out×N_in}
    describing the shared XOR-gate network (random fill, or exactly
    ``N_tap`` ones per row as §4 recommends).
  * ``decrypt_bits`` — Boolean decryption y = M⊕ x over GF(2), expressed in
    the ±1 domain of Eq. (2)/(4): y_r = (-1)^{n_r-1} ∏_{j∈taps(r)} sign(x_j).
  * ``flexor_decrypt`` — the *trainable* decrypt with the paper's custom
    gradient (Eq. (6) by default; Eq. (5) exact-tanh, STE and the "analog"
    relaxation of Fig. 5 as ablations).

Shapes: encrypted weights live as ``(slices, N_in)`` real tensors; the
decrypt produces ``(slices, N_out)`` quantized bits in {-1, +1}, which the
quantizer reshapes into weight tensors (see quant.py).

The ±1-domain identity used throughout (MXU-friendly — a {0,1} matmul plus a
parity, instead of a gather-product):

    y[s, r] = (-1)^(ntap_r - 1) * ∏_{j∈taps(r)} sign(x[s, j])
            = 1 - 2 * ((negcount[s, r] + ntap_r - 1) mod 2)

where negcount = 1[x<0] @ M⊕ᵀ counts selected negative inputs.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

__all__ = [
    "make_mxor",
    "mxor_parity",
    "hamming_distance_stats",
    "decrypt_bits",
    "flexor_decrypt",
    "num_slices",
    "bits_per_weight",
]


# ---------------------------------------------------------------------------
# M⊕ construction (fixed before training; shared across all slices/layers)
# ---------------------------------------------------------------------------

def make_mxor(n_out: int, n_in: int, *, n_tap: int | None = None,
              seed: int = 0) -> np.ndarray:
    """Build the XOR-gate network matrix M⊕ ∈ {0,1}^{n_out × n_in}.

    ``n_tap=None`` reproduces the paper's Fig. 4 setting (each entry iid
    Bernoulli(1/2), rows forced non-zero); an integer ``n_tap`` places exactly
    that many 1s per row (§4 technique 1, ``N_tap=2`` recommended).

    The matrix is host-side data (numpy, int8): it is *fixed* and baked into
    the lowered HLO as a constant, and serialized raw into FXR containers so
    Rust decryption uses the identical network.
    """
    if n_out < n_in:
        raise ValueError(f"n_out ({n_out}) must be >= n_in ({n_in}) for compression")
    if n_tap is not None and not (1 <= n_tap <= n_in):
        raise ValueError(f"n_tap ({n_tap}) must be in [1, n_in={n_in}]")
    rng = np.random.default_rng(seed)
    m = np.zeros((n_out, n_in), dtype=np.int8)
    if n_tap is None:
        for r in range(n_out):
            row = rng.integers(0, 2, size=n_in)
            while row.sum() == 0:  # an all-zero row decodes a constant bit
                row = rng.integers(0, 2, size=n_in)
            m[r] = row
    else:
        for r in range(n_out):
            taps = rng.choice(n_in, size=n_tap, replace=False)
            m[r, taps] = 1
    return m


def mxor_parity(m: np.ndarray) -> np.ndarray:
    """(-1)^(ntap_r - 1) per row — the constant sign of Eq. (4)."""
    ntap = m.sum(axis=1)
    return np.where((ntap - 1) % 2 == 0, 1.0, -1.0).astype(np.float32)


def hamming_distance_stats(m: np.ndarray) -> dict:
    """Pairwise Hamming distances between the rows of M⊕ viewed as linear
    Boolean functions (paper Eq. (1): d_H(f1,f2) = 2^{N_in-1} iff the tap
    sets differ; more generally 2^{N_in-1} for any distinct pair, 0 for
    identical rows — so the interesting statistic is how many row pairs are
    *distinct*, plus tap-overlap structure)."""
    n_out, n_in = m.shape
    dists = []
    overlaps = []
    for i in range(n_out):
        for j in range(i + 1, n_out):
            diff = int(np.bitwise_xor(m[i], m[j]).sum())
            # d_H between linear boolean functions f_i, f_j over {0,1}^n_in:
            # 0 if identical tap sets, else 2^(n_in-1).
            dists.append(0 if diff == 0 else 2 ** (n_in - 1))
            overlaps.append(int((m[i] & m[j]).sum()))
    return {
        "n_out": n_out,
        "n_in": n_in,
        "mean_hamming": float(np.mean(dists)) if dists else 0.0,
        "distinct_row_pairs": int(sum(1 for d in dists if d > 0)),
        "total_row_pairs": len(dists),
        "mean_tap_overlap": float(np.mean(overlaps)) if overlaps else 0.0,
        "ntap_per_row": [int(x) for x in m.sum(axis=1)],
    }


def num_slices(n_weights: int, n_out: int) -> int:
    """How many N_in-bit slices cover ``n_weights`` quantized bits."""
    return -(-n_weights // n_out)  # ceil


def bits_per_weight(q: int, n_in: int, n_out: int) -> float:
    """Effective fractional rate: q * N_in / N_out bits per weight."""
    return q * n_in / n_out


# ---------------------------------------------------------------------------
# Decryption — forward Boolean semantics
# ---------------------------------------------------------------------------

def decrypt_bits(x_sign: jnp.ndarray, m: np.ndarray) -> jnp.ndarray:
    """Pure Boolean decrypt in the ±1 domain (Eq. (2)/(4) forward).

    x_sign: (slices, N_in) in {-1, +1}.  Returns (slices, N_out) in {-1,+1}.
    """
    mf = jnp.asarray(m, dtype=x_sign.dtype)              # (N_out, N_in)
    neg = (1.0 - x_sign) * 0.5                           # 1 where negative
    negcount = neg @ mf.T                                # (slices, N_out)
    ntap = mf.sum(axis=1)                                # (N_out,)
    par = jnp.mod(negcount + ntap - 1.0, 2.0)
    return 1.0 - 2.0 * par


# ---------------------------------------------------------------------------
# Trainable decrypt — custom VJPs (Eq. 6 default; Eq. 5 / STE / analog ablations)
# ---------------------------------------------------------------------------

def _fwd_sign(x: jnp.ndarray, m: np.ndarray) -> jnp.ndarray:
    """Forward: y = (-1)^(n-1) ∏ sign(x) per row of M⊕ (Eq. 4)."""
    return decrypt_bits(jnp.sign(jnp.where(x == 0, 1e-12, x)), m)


def flexor_decrypt(x: jnp.ndarray, s_tanh: jnp.ndarray, m: np.ndarray,
                   *, mode: str = "flexor", grad: str = "approx") -> jnp.ndarray:
    """Trainable XOR decrypt of encrypted weights.

    Args:
      x:      (slices, N_in) real encrypted weights.
      s_tanh: scalar S_tanh (traced — scheduled by the Rust coordinator).
      m:      M⊕ as numpy {0,1}, baked as a constant.
      mode:   'flexor' (paper: sign fwd, ∂tanh bwd), 'ste' (sign fwd,
              identity bwd), 'analog' (tanh fwd+bwd, then STE binarize —
              Fig. 5's middle column).
      grad:   for mode='flexor': 'approx' = Eq. (6) (default, what the paper
              trains with), 'exact' = Eq. (5) full tanh product.

    Returns (slices, N_out) quantized bits; exactly ±1 for 'flexor'/'ste'.
    """
    mf = np.asarray(m, dtype=np.float32)
    if mode == "flexor":
        fn = _flexor_vjp_approx if grad == "approx" else _flexor_vjp_exact
        return fn(x, s_tanh, mf)
    if mode == "ste":
        return _ste_vjp(x, s_tanh, mf)
    if mode == "analog":
        return _analog(x, s_tanh, mf)
    raise ValueError(f"unknown mode {mode!r}")


# --- mode='flexor', grad='approx' (Eq. 6) ----------------------------------
#
# ∂y_r/∂x_i = S (-1)^(n-1) (1 - tanh²(x_i S)) ∏_{j≠i} sign(x_j)
#           = S (1 - tanh²(x_i S)) · y_r · sign(x_i)
# so  dL/dx_i = S (1-tanh²(x_i S)) sign(x_i) · Σ_r M[r,i] g_r y_r
# — a single (g*y) @ M⊕ matmul; no per-tap gathers.

@jax.custom_vjp
def _flexor_vjp_approx(x, s_tanh, m):
    return _fwd_sign(x, m)


def _flexor_approx_fwd(x, s_tanh, m):
    y = _fwd_sign(x, m)
    return y, (x, s_tanh, m, y)


def _flexor_approx_bwd(res, g):
    x, s_tanh, m, y = res
    t = jnp.tanh(x * s_tanh)
    sech2 = 1.0 - t * t
    sgn = jnp.sign(jnp.where(x == 0, 1e-12, x))
    gy = g * y                                   # (slices, N_out)
    dx = (gy @ jnp.asarray(m)) * s_tanh * sech2 * sgn
    return dx, jnp.zeros_like(s_tanh), None


_flexor_vjp_approx.defvjp(_flexor_approx_fwd, _flexor_approx_bwd)


# --- mode='flexor', grad='exact' (Eq. 5) ------------------------------------
#
# ∂y_r/∂x_i = S (-1)^(n-1) (1 - tanh²(x_i S)) ∏_{j∈taps, j≠i} tanh(x_j S)
# Computed with a masked full product divided by tanh(x_i S) (guarded).

@jax.custom_vjp
def _flexor_vjp_exact(x, s_tanh, m):
    return _fwd_sign(x, m)


def _flexor_exact_fwd(x, s_tanh, m):
    return _fwd_sign(x, m), (x, s_tanh, m)


def _flexor_exact_bwd(res, g):
    x, s_tanh, m = res
    mj = jnp.asarray(m)                                    # (N_out, N_in)
    t = jnp.tanh(x * s_tanh)                               # (slices, N_in)
    t_safe = jnp.where(jnp.abs(t) < 1e-6, jnp.sign(t) * 1e-6 + 1e-12, t)
    # full tanh product per row: ∏_{j∈taps(r)} t_j, via where(M,t,1)
    tb = jnp.where(mj[None, :, :] > 0, t[:, None, :], 1.0)  # (s, N_out, N_in)
    full = jnp.prod(tb, axis=2)                             # (s, N_out)
    ntap = mj.sum(axis=1)
    par = jnp.where(jnp.mod(ntap - 1, 2) == 0, 1.0, -1.0)   # (-1)^(n-1)
    sech2 = 1.0 - t * t
    # dL/dx_i = S par_r (1-tanh²(x_i)) * full_r / t_i summed over rows with M=1
    contrib = (g * par[None, :] * full)                     # (s, N_out)
    dx = s_tanh * sech2 / t_safe * (contrib @ mj)
    return dx, jnp.zeros_like(s_tanh), None


_flexor_vjp_exact.defvjp(_flexor_exact_fwd, _flexor_exact_bwd)


# --- mode='ste' (Fig. 5 left column) ----------------------------------------
#
# Forward sign-product; backward treats each sign() as identity:
# ∂y_r/∂x_i = (-1)^(n-1) ∏_{j≠i} sign(x_j) = y_r · sign(x_i)

@jax.custom_vjp
def _ste_vjp(x, s_tanh, m):
    return _fwd_sign(x, m)


def _ste_fwd(x, s_tanh, m):
    y = _fwd_sign(x, m)
    return y, (x, m, y)


def _ste_bwd(res, g):
    x, m, y = res
    sgn = jnp.sign(jnp.where(x == 0, 1e-12, x))
    dx = ((g * y) @ jnp.asarray(m)) * sgn
    return dx, jnp.zeros(()), None


_ste_vjp.defvjp(_ste_fwd, _ste_bwd)


# --- mode='analog' (Fig. 5 middle column) ------------------------------------
#
# XOR modeled in ℝ: y = (-1)^(n-1) ∏ tanh(x_j S) for both fwd and bwd (plain
# autodiff), then the real output is binarized through a standard STE.

@jax.custom_vjp
def _binarize_ste(y):
    return jnp.sign(jnp.where(y == 0, 1e-12, y))


def _binarize_fwd(y):
    return _binarize_ste(y), None


def _binarize_bwd(_, g):
    return (g,)


_binarize_ste.defvjp(_binarize_fwd, _binarize_bwd)


def _analog(x, s_tanh, m):
    mj = jnp.asarray(m)
    t = jnp.tanh(x * s_tanh)
    tb = jnp.where(mj[None, :, :] > 0, t[:, None, :], 1.0)
    full = jnp.prod(tb, axis=2)
    ntap = mj.sum(axis=1)
    par = jnp.where(jnp.mod(ntap - 1, 2) == 0, 1.0, -1.0)
    return _binarize_ste(par[None, :] * full)
