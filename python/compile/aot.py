"""AOT compiler: lower every experiment config to HLO text + metadata.

For each config in configs.py this emits, under ``artifacts/<name>/``:

  * ``train_step.hlo.txt`` — ONE HLO for loss+grads+optimizer update, with a
    flat-leaf calling convention (see below),
  * ``eval_step.hlo.txt``  — eval loss / top-1 / top-5 counts,
  * ``init.bin``           — initial (params, opt_state, bn_state) leaves,
  * ``meta.json``          — leaf layout, M⊕ matrices, storage accounting,

plus a global ``artifacts/manifest.json`` the Rust runtime indexes.

Interchange is HLO **text**, not serialized protos: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published `xla` crate binds) rejects; the text parser reassigns
ids (see /opt/xla-example/README.md).

Flat calling convention (what Rust marshals, in order):

  train inputs : state leaves (params ++ opt ++ bn) ++ [x, y, lr, s_tanh, relax_lambda]
  train outputs: state leaves' ++ [loss, correct]        (positional feedback)
  eval inputs  : params ++ bn ++ [x, y, s_tanh, relax_lambda]
  eval outputs : [loss, correct, top5_correct]

Python runs only here, at build time.  ``make artifacts`` is incremental: a
config is skipped when its meta.json already records the same config hash.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import struct
import sys
from pathlib import Path

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import configs as config_registry
from . import quant, train
from . import models as model_zoo

MAGIC = b"FXIN"
DTYPE_TAGS = {"float32": 0, "int32": 1}


# ---------------------------------------------------------------------------
# config -> Quantizer
# ---------------------------------------------------------------------------

def make_quantizer(qcfg: dict) -> quant.Quantizer:
    kind = qcfg["kind"]
    if kind != "flexor":
        return quant.Quantizer(kind)
    base = quant.FlexorSpec(
        qcfg["q"], qcfg["n_in"], qcfg["n_out"], n_tap=qcfg.get("n_tap", 2),
        seed=qcfg.get("seed", 7), mode=qcfg.get("mode", "flexor"),
        grad=qcfg.get("grad", "approx"))
    specs = {}
    for gi, grp in enumerate(qcfg.get("groups", [])):
        spec = quant.FlexorSpec(
            qcfg["q"], grp["n_in"], grp.get("n_out", qcfg["n_out"]),
            n_tap=qcfg.get("n_tap", 2), seed=qcfg.get("seed", 7) + 100 * (gi + 1),
            mode=qcfg.get("mode", "flexor"), grad=qcfg.get("grad", "approx"))
        for li in grp["layers"]:
            specs[li] = spec
    return quant.Quantizer("flexor", spec=base, specs=specs,
                           use_pallas=qcfg.get("use_pallas", False))


# ---------------------------------------------------------------------------
# lowering helpers
# ---------------------------------------------------------------------------

def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    # print_large_constants=True is ESSENTIAL: the default printer elides
    # big array constants as `constant({...})`, which xla_extension 0.5.1's
    # HLO parser silently zero-fills — baked M⊕ tables would decode as
    # all-zeros (discovered the hard way; see EXPERIMENTS.md §Debugging).
    text = comp.as_hlo_text(print_large_constants=True)
    assert "{...}" not in text, "HLO printer elided a constant"
    return text


def flatten_fn(fn, example_args):
    """Wrap fn so its signature is the flat leaf list of example_args."""
    flat, tdef = jax.tree.flatten(example_args)

    def wrapped(*leaves):
        out = fn(*jax.tree.unflatten(tdef, list(leaves)))
        return tuple(jax.tree.leaves(out))

    return wrapped, flat


def leaf_meta(tree, role: str):
    out = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        out.append({
            "role": role,
            "path": jax.tree_util.keystr(path),
            "shape": list(leaf.shape),
            "dtype": str(leaf.dtype),
        })
    return out


def write_init_bin(path: Path, trees):
    """Serialize the flat leaves of ``trees`` (a tuple of pytrees)."""
    leaves = jax.tree.leaves(trees)
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<II", 1, len(leaves)))
        for leaf in leaves:
            a = np.asarray(leaf)
            tag = DTYPE_TAGS[str(a.dtype)]
            f.write(struct.pack("<BBH", tag, a.ndim, 0))
            f.write(struct.pack(f"<{a.ndim}I", *a.shape) if a.ndim else b"")
            f.write(a.astype("<f4" if tag == 0 else "<i4").tobytes())


# ---------------------------------------------------------------------------
# storage accounting (Table 5's compression-ratio column)
# ---------------------------------------------------------------------------

def storage_report(cfg, qz, model, mk):
    qshapes = model.quantized_layer_shapes(**mk) if hasattr(
        model, "quantized_layer_shapes") else []
    layers = []
    enc_bits = 0
    qweights = 0
    alpha_bits = 0
    for idx, shape in qshapes:
        n = int(np.prod(shape))
        bits = qz.storage_bits(n, layer_idx=idx)
        layers.append({"idx": idx, "shape": list(shape), "weights": n,
                       "stored_bits": bits,
                       "bits_per_weight": bits / n})
        enc_bits += bits
        qweights += n
        if qz.kind == "flexor":
            alpha_bits += 32 * qz.spec_for(idx).q * shape[-1]
    return {
        "layers": layers,
        "quantized_weights": qweights,
        "encrypted_bits": enc_bits,
        "alpha_bits": alpha_bits,
        "bits_per_weight": enc_bits / qweights if qweights else 32.0,
        "compression_ratio_weights_only":
            (32.0 * qweights / enc_bits) if enc_bits else 1.0,
        "compression_ratio_with_alpha":
            (32.0 * qweights / (enc_bits + alpha_bits)) if enc_bits else 1.0,
    }


def mxor_report(cfg, qz, model, mk):
    if qz.kind != "flexor":
        return None
    def spec_json(spec):
        return {"q": spec.q, "n_in": spec.n_in, "n_out": spec.n_out,
                "n_tap": spec.n_tap, "mode": spec.mode, "grad": spec.grad,
                "bits_per_weight": spec.bits_per_weight,
                "mxor": [[[int(v) for v in row] for row in m]
                          for m in spec.mxor]}
    rep = {"default": spec_json(qz.spec)}
    groups = {}
    for idx, spec in qz.specs.items():
        groups[str(idx)] = spec_json(spec)
    if groups:
        rep["per_layer"] = groups
    return rep


# ---------------------------------------------------------------------------
# per-config build
# ---------------------------------------------------------------------------

def config_hash(cfg: dict) -> str:
    return hashlib.sha256(
        json.dumps(cfg, sort_keys=True).encode()).hexdigest()[:16]


def build_config(cfg: dict, out_root: Path, force: bool = False) -> bool:
    """Returns True if (re)built, False if up-to-date."""
    name = cfg["name"]
    cdir = out_root / name
    meta_path = cdir / "meta.json"
    h = config_hash(cfg)
    if not force and meta_path.exists():
        try:
            if json.loads(meta_path.read_text()).get("config_hash") == h:
                return False
        except json.JSONDecodeError:
            pass
    cdir.mkdir(parents=True, exist_ok=True)

    qz = make_quantizer(cfg["quantizer"])
    mk = dict(cfg["model_kwargs"])
    model = model_zoo.get(cfg["model"])
    if cfg["model"].startswith("resnet"):
        mk.setdefault("in_ch", cfg["in_ch"])
    elif cfg["model"] == "lenet5":
        mk.setdefault("in_hw", cfg["in_hw"])
        mk.setdefault("in_ch", cfg["in_ch"])
        mk.setdefault("num_classes", cfg["num_classes"])
    elif cfg["model"] == "mlp":
        mk.setdefault("num_classes", cfg["num_classes"])

    init_fn, train_step, eval_step = train.build(
        cfg["model"], qz, optimizer=cfg["optimizer"],
        weight_decay=cfg["weight_decay"], model_kwargs=mk)

    params, opt, bn = init_fn(cfg["seed"])
    b = cfg["batch"]
    x_spec = jax.ShapeDtypeStruct((b, cfg["in_hw"], cfg["in_hw"], cfg["in_ch"]),
                                  jnp.float32)
    if cfg["model"] == "mlp":
        d_in = mk.get("d_in", 784)
        x_spec = jax.ShapeDtypeStruct((b, d_in), jnp.float32)
    y_spec = jax.ShapeDtypeStruct((b,), jnp.int32)
    s_spec = jax.ShapeDtypeStruct((), jnp.float32)

    specs = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                         (params, opt, bn))

    train_args = (*specs, x_spec, y_spec, s_spec, s_spec, s_spec)
    train_flat, train_leaves = flatten_fn(train_step, train_args)
    train_hlo = to_hlo_text(
        jax.jit(train_flat, keep_unused=True).lower(*train_leaves))

    eval_args = (specs[0], specs[2], x_spec, y_spec, s_spec, s_spec)
    eval_flat, eval_leaves = flatten_fn(eval_step, eval_args)
    eval_hlo = to_hlo_text(
        jax.jit(eval_flat, keep_unused=True).lower(*eval_leaves))

    (cdir / "train_step.hlo.txt").write_text(train_hlo)
    (cdir / "eval_step.hlo.txt").write_text(eval_hlo)
    write_init_bin(cdir / "init.bin", (params, opt, bn))

    n_p = len(jax.tree.leaves(params))
    n_o = len(jax.tree.leaves(opt))
    n_b = len(jax.tree.leaves(bn))
    meta = {
        "config_hash": h,
        "config": cfg,
        "files": {"train": "train_step.hlo.txt", "eval": "eval_step.hlo.txt",
                  "init": "init.bin"},
        "batch": b,
        "input": {"shape": list(x_spec.shape), "classes": cfg["num_classes"]},
        "leaves": (leaf_meta(params, "params") + leaf_meta(opt, "opt")
                   + leaf_meta(bn, "bn")),
        "counts": {"params": n_p, "opt": n_o, "bn": n_b},
        "train_io": {
            "inputs": n_p + n_o + n_b + 5,
            "outputs": n_p + n_o + n_b + 2,
            "state_feedback": n_p + n_o + n_b,
            "scalar_order": ["lr", "s_tanh", "relax_lambda"],
        },
        "eval_io": {"inputs": n_p + n_b + 4, "outputs": 3,
                    "scalar_order": ["s_tanh", "relax_lambda"]},
        "storage": storage_report(cfg, qz, model, mk),
        "flexor": mxor_report(cfg, qz, model, mk),
    }
    meta_path.write_text(json.dumps(meta, indent=1))
    return True


def write_manifest(out_root: Path):
    entries = {}
    for meta_path in sorted(out_root.glob("*/meta.json")):
        try:
            meta = json.loads(meta_path.read_text())
        except json.JSONDecodeError:
            continue
        entries[meta["config"]["name"]] = {
            "dir": meta_path.parent.name,
            "model": meta["config"]["model"],
            "quantizer": meta["config"]["quantizer"]["kind"],
            "bits_per_weight": meta["storage"]["bits_per_weight"],
            "tags": meta["config"]["tags"],
        }
    (out_root / "manifest.json").write_text(
        json.dumps({"version": 1, "configs": entries}, indent=1))


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--set", dest="set_name", default="default",
                    help="default | full | all | <tag>")
    ap.add_argument("--only", default=None,
                    help="comma-separated config names")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)

    only = args.only.split(",") if args.only else None
    cfgs = config_registry.select(args.set_name, only)
    if args.list:
        for c in cfgs:
            print(f"{c['name']:36s} {c['model']:12s} "
                  f"{c['quantizer']['kind']:12s} tags={','.join(c['tags'])}")
        return 0

    out_root = Path(args.out)
    out_root.mkdir(parents=True, exist_ok=True)
    built = skipped = 0
    for c in cfgs:
        if build_config(c, out_root, force=args.force):
            built += 1
            print(f"[aot] built {c['name']}")
        else:
            skipped += 1
    write_manifest(out_root)
    print(f"[aot] done: {built} built, {skipped} up-to-date "
          f"-> {out_root / 'manifest.json'}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
