"""Minimal functional NN substrate (pure JAX, no flax dependency).

Everything is (params-pytree, state-pytree, apply-fn) so whole train steps
lower into a single HLO.  Conventions:

  * activations NHWC, conv weights HWIO (k, k, C_in, C_out), dense (in, out)
  * BatchNorm keeps running stats in a separate `state` pytree threaded
    through the train step (the Rust coordinator round-trips it like params)
  * the Quantizer object (see quant.Quantizer) produces each quantized
    layer's weight tensor from its quantizer-specific params
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax

__all__ = ["conv2d", "max_pool", "avg_pool_global", "init_bn", "batch_norm",
           "init_dense_fp", "dense_fp", "relu", "he_normal"]

BN_MOMENTUM = 0.9
BN_EPS = 1e-5


def he_normal(key, shape, gain: float = 1.0):
    fan_in = int(np.prod(shape[:-1]))
    return jax.random.normal(key, shape) * gain * (2.0 / fan_in) ** 0.5


def relu(x):
    return jnp.maximum(x, 0.0)


def conv2d(x, w, stride: int = 1, padding: str = "SAME"):
    """NHWC conv with HWIO weights."""
    return lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def max_pool(x, window: int = 2, stride: int = 2):
    return lax.reduce_window(
        x, -jnp.inf, lax.max, (1, window, window, 1), (1, stride, stride, 1),
        "VALID")


def avg_pool_global(x):
    """NHWC → NC global average pool."""
    return x.mean(axis=(1, 2))


# --- BatchNorm ---------------------------------------------------------------

def init_bn(c: int):
    params = {"scale": jnp.ones((c,)), "bias": jnp.zeros((c,))}
    state = {"mean": jnp.zeros((c,)), "var": jnp.ones((c,))}
    return params, state


def batch_norm(p, s, x, train: bool):
    """Returns (y, new_state).  x: (..., C)."""
    if train:
        axes = tuple(range(x.ndim - 1))
        mean = x.mean(axis=axes)
        var = x.var(axis=axes)
        new_s = {
            "mean": BN_MOMENTUM * s["mean"] + (1 - BN_MOMENTUM) * mean,
            "var": BN_MOMENTUM * s["var"] + (1 - BN_MOMENTUM) * var,
        }
    else:
        mean, var = s["mean"], s["var"]
        new_s = s
    y = (x - mean) * lax.rsqrt(var + BN_EPS) * p["scale"] + p["bias"]
    return y, new_s


# --- Full-precision dense (first/last layers stay FP, paper §4) ---------------

def init_dense_fp(key, d_in: int, d_out: int):
    return {"w": he_normal(key, (d_in, d_out)), "b": jnp.zeros((d_out,))}


def dense_fp(p, x):
    return x @ p["w"] + p["b"]
