"""LeNet-5 as used in the paper's MNIST study (§3, Fig. 4/12/13).

32C5 - MP2 - 64C5 - MP2 - 512FC - 10SoftMax; *every* layer carries an
XOR-gate network ("each layer is accompanied by an XOR-gate network"), with
per-output-channel scaling factors (the α of the 1-bit binary code) —
initialised to 0.2 per the paper.  No dropout, no BN (faithful to the
original LeNet recipe the paper uses; α carries the scale).

``width_mult`` scales channel counts for CPU-budget runs (DESIGN.md §5):
the default 1.0 is the paper's exact architecture.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import nn


def _dims(width_mult: float, in_hw: int = 28):
    c1 = max(4, int(32 * width_mult))
    c2 = max(4, int(64 * width_mult))
    fc = max(16, int(512 * width_mult))
    flat = (in_hw // 4) * (in_hw // 4) * c2
    return c1, c2, fc, flat


def quantized_layer_shapes(width_mult: float = 1.0, num_classes: int = 10,
                           in_hw: int = 28, in_ch: int = 1):
    c1, c2, fc, flat = _dims(width_mult, in_hw)
    return [
        (0, (5, 5, in_ch, c1)),
        (1, (5, 5, c1, c2)),
        (2, (flat, fc)),
        (3, (fc, num_classes)),
    ]


def init(key, qz, width_mult: float = 1.0, num_classes: int = 10,
         in_hw: int = 28, in_ch: int = 1):
    shapes = quantized_layer_shapes(width_mult, num_classes, in_hw, in_ch)
    keys = jax.random.split(key, len(shapes))
    params = {"layers": [qz.init(k, s, layer_idx=i)
                         for k, (i, s) in zip(keys, shapes)],
              "bias": [jnp.zeros((s[-1],)) for _, s in shapes]}
    return params, {}


def apply(params, state, x, qz, ctx, train: bool,
          width_mult: float = 1.0, num_classes: int = 10,
          in_hw: int = 28, in_ch: int = 1):
    shapes = quantized_layer_shapes(width_mult, num_classes, in_hw, in_ch)
    if x.ndim == 2:  # flat input -> NHWC
        x = x.reshape(x.shape[0], in_hw, in_hw, in_ch)
    w0 = qz(params["layers"][0], shapes[0][1], ctx, layer_idx=0)
    h = nn.relu(nn.conv2d(x, w0) + params["bias"][0])
    h = nn.max_pool(h)
    w1 = qz(params["layers"][1], shapes[1][1], ctx, layer_idx=1)
    h = nn.relu(nn.conv2d(h, w1) + params["bias"][1])
    h = nn.max_pool(h)
    h = h.reshape(h.shape[0], -1)
    w2 = qz(params["layers"][2], shapes[2][1], ctx, layer_idx=2)
    h = nn.relu(h @ w2 + params["bias"][2])
    w3 = qz(params["layers"][3], shapes[3][1], ctx, layer_idx=3)
    logits = h @ w3 + params["bias"][3]
    return logits, {}
