"""Model zoo: every architecture the paper evaluates, quantizer-agnostic.

Each model module exposes:

    init(key, qz, **cfg)  -> (params, bn_state)
    apply(params, bn_state, x, qz, ctx, train) -> (logits, new_bn_state)
    quantized_layer_shapes(**cfg) -> [(layer_idx, shape), ...]

``qz`` is a quant.Quantizer; ``ctx`` carries scheduled scalars
({'s_tanh': f32, 'relax_lambda': f32}) that the Rust coordinator feeds as
HLO inputs every step.  Quantized layers are indexed in definition order so
mixed-precision specs (Table 2 / Table 3 footnote) can target layer groups.
"""

from . import mlp, lenet, resnet

REGISTRY = {
    "mlp": mlp,
    "lenet5": lenet,
    "resnet20": resnet.resnet20,
    "resnet32": resnet.resnet32,
    "resnet8": resnet.resnet8,
    "resnet14": resnet.resnet14,
    "resnet18img": resnet.resnet18img,
    "resnet10img": resnet.resnet10img,
}


def get(name: str):
    try:
        return REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown model {name!r}; have {sorted(REGISTRY)}")
