"""ResNet family: CIFAR-style ResNet-20/32 (§4) and ImageNet-style
ResNet-18 (§5), plus width/depth-scaled variants for the CPU budget
(DESIGN.md §5 — structure is faithful, widths/depths are config).

CIFAR ResNet (He et al.): 3×3 stem → 3 stages × n BasicBlocks (depth 6n+2),
widths (16,32,64), stride-2 at stage entry, global avg pool, FC head.
ImageNet-style: stem → 4 stages × [2,2,2,2] BasicBlocks, widths w·(1,2,4,8).

Quantization: every conv except the stem, and not the FC head (paper: "All
layers, except the first and the last layers, are followed by FleXOR
components").  Downsample 1×1 convs are quantized (Table 3 footnote assigns
them their own bits/weight).  Quantized layers are indexed in definition
order so Table 2's layer-group specs can address them.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import nn


class _ResNet:
    def __init__(self, name: str, blocks_per_stage, widths, in_hw: int,
                 num_classes: int = 10):
        self.name = name
        self.blocks = list(blocks_per_stage)
        self.widths = list(widths)
        self.in_hw = in_hw
        self.num_classes = num_classes

    # ---- static layer plan --------------------------------------------------

    def _plan(self, in_ch: int = 3):
        """[(kind, shape, stride)] for every conv in definition order.

        kind ∈ {'stem','q','qds'} — qds is a quantized 1×1 downsample.
        """
        plan = [("stem", (3, 3, in_ch, self.widths[0]), 1)]
        c_in = self.widths[0]
        for si, (n, w) in enumerate(zip(self.blocks, self.widths)):
            for bi in range(n):
                stride = 2 if (si > 0 and bi == 0) else 1
                plan.append(("q", (3, 3, c_in, w), stride))
                plan.append(("q", (3, 3, w, w), 1))
                if stride != 1 or c_in != w:
                    plan.append(("qds", (1, 1, c_in, w), stride))
                c_in = w
        return plan

    def quantized_layer_shapes(self, in_ch: int = 3, **_):
        out, qi = [], 0
        for kind, shape, _s in self._plan(in_ch):
            if kind != "stem":
                out.append((qi, shape))
                qi += 1
        return out

    # ---- init ----------------------------------------------------------------

    def init(self, key, qz, in_ch: int = 3, **_):
        plan = self._plan(in_ch)
        keys = jax.random.split(key, len(plan) + 1)
        params = {"convs": [], "bn": [], "head": None, "stem": None}
        state = {"bn": []}
        qi = 0
        for k, (kind, shape, _s) in zip(keys, plan):
            if kind == "stem":
                params["stem"] = {"w": nn.he_normal(k, shape)}
            else:
                params["convs"].append(qz.init(k, shape, layer_idx=qi))
                qi += 1
            bp, bs = nn.init_bn(shape[-1])
            params["bn"].append(bp)
            state["bn"].append(bs)
        params["head"] = nn.init_dense_fp(keys[-1], self.widths[-1],
                                          self.num_classes)
        return params, state

    # ---- apply ---------------------------------------------------------------

    def apply(self, params, state, x, qz, ctx, train: bool, in_ch: int = 3, **_):
        plan = self._plan(in_ch)
        new_bn = [None] * len(plan)
        li = 0   # conv index (into plan/bn)
        qi = 0   # quantized-conv index (into params['convs'])

        def bn(h, i):
            y, s = nn.batch_norm(params["bn"][i], state["bn"][i], h, train)
            new_bn[i] = s
            return y

        def qconv(h, shape, stride):
            nonlocal qi
            w = qz(params["convs"][qi], shape, ctx, layer_idx=qi)
            qi += 1
            return nn.conv2d(h, w, stride=stride)

        # stem
        kind, shape, stride = plan[li]
        h = nn.relu(bn(nn.conv2d(x, params["stem"]["w"], stride=stride), li))
        li += 1

        c_in = self.widths[0]
        for si, (n, w) in enumerate(zip(self.blocks, self.widths)):
            for bi in range(n):
                stride = 2 if (si > 0 and bi == 0) else 1
                identity = h
                _, s1, _ = plan[li]
                out = nn.relu(bn(qconv(h, s1, stride), li)); li += 1
                _, s2, _ = plan[li]
                out = bn(qconv(out, s2, 1), li); li += 1
                if stride != 1 or c_in != w:
                    _, sd, _ = plan[li]
                    identity = bn(qconv(h, sd, stride), li); li += 1
                h = nn.relu(out + identity)
                c_in = w

        pooled = nn.avg_pool_global(h)
        logits = nn.dense_fp(params["head"], pooled)
        return logits, {"bn": new_bn}


# Paper architectures
resnet20 = _ResNet("resnet20", (3, 3, 3), (16, 32, 64), in_hw=32)
resnet32 = _ResNet("resnet32", (5, 5, 5), (16, 32, 64), in_hw=32)
resnet18img = _ResNet("resnet18img", (2, 2, 2, 2), (64, 128, 256, 512),
                      in_hw=64, num_classes=20)

# CPU-budget scaled variants (same structure, smaller)
resnet8 = _ResNet("resnet8", (1, 1, 1), (8, 16, 32), in_hw=32)
resnet14 = _ResNet("resnet14", (2, 2, 2), (16, 32, 64), in_hw=32)
resnet10img = _ResNet("resnet10img", (1, 1, 1, 1), (16, 32, 64, 128),
                      in_hw=64, num_classes=20)
