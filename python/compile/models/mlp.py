"""MLP — the smallest end-to-end testbed (unit tests + quickstart).

input (N, D) → [quantized dense → BN → relu] × len(hidden) → FP dense head.
First hidden layer is quantized too (as in the paper's LeNet/MNIST setup
where every layer carries an XOR network).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import nn


def quantized_layer_shapes(d_in: int = 784, hidden=(256, 128),
                           num_classes: int = 10):
    shapes = []
    d = d_in
    for i, h in enumerate(hidden):
        shapes.append((i, (d, h)))
        d = h
    return shapes


def init(key, qz, d_in: int = 784, hidden=(256, 128), num_classes: int = 10):
    keys = jax.random.split(key, len(hidden) + 1)
    params = {"layers": [], "bn": []}
    state = {"bn": []}
    d = d_in
    for i, h in enumerate(hidden):
        params["layers"].append(qz.init(keys[i], (d, h), layer_idx=i))
        bp, bs = nn.init_bn(h)
        params["bn"].append(bp)
        state["bn"].append(bs)
        d = h
    params["head"] = nn.init_dense_fp(keys[-1], d, num_classes)
    return params, state


def apply(params, state, x, qz, ctx, train: bool,
          d_in: int = 784, hidden=(256, 128), num_classes: int = 10):
    new_bn = []
    h = x.reshape(x.shape[0], -1)
    d = d_in
    for i, width in enumerate(hidden):
        w = qz(params["layers"][i], (d, width), ctx, layer_idx=i)
        h = h @ w
        h, bs = nn.batch_norm(params["bn"][i], state["bn"][i], h, train)
        new_bn.append(bs)
        h = nn.relu(h)
        d = width
    logits = nn.dense_fp(params["head"], h)
    return logits, {"bn": new_bn}
