"""Experiment configuration registry for the AOT compiler.

Every (model × quantizer × shape) combination the Rust side can run must be
lowered ahead of time; this module enumerates them.  Sets:

  * ``default`` — what plain ``make artifacts`` builds: the quickstart, the
    e2e driver and the small kernels-enabled config.  Fast to build.
  * ``full``    — everything the table/figure runners need (Fig. 4-8,
    Tables 1-7).  ``make artifacts SET=full``.

S_tanh / learning rate / BinaryRelax λ are *runtime scalars* (HLO inputs),
so schedule sweeps (Fig. 6, warmup ablations) reuse one artifact.  Only
shape-changing knobs (q, N_in, N_out, model, batch) need separate configs.

Dataset geometry convention (matches rust/src/data):
  digits   — 28×28×1, 10 classes (MNIST substitute)
  shapes32 — 32×32×3, 10 classes (CIFAR-10 substitute)
  shapes64 — 64×64×3, 20 classes (ImageNet substitute)
"""

from __future__ import annotations


def _flexor(q, n_in, n_out, *, n_tap=2, seed=7, mode="flexor", grad="approx",
            use_pallas=False, groups=None):
    d = {"kind": "flexor", "q": q, "n_in": n_in, "n_out": n_out,
         "n_tap": n_tap, "seed": seed, "mode": mode, "grad": grad,
         "use_pallas": use_pallas}
    if groups:
        d["groups"] = groups
    return d


def _cfg(name, model, quantizer, *, batch=64, optimizer="sgd",
         weight_decay=1e-5, seed=0, in_hw=32, in_ch=3, num_classes=10,
         model_kwargs=None, tags=()):
    return {
        "name": name, "model": model, "quantizer": quantizer,
        "batch": batch, "optimizer": optimizer,
        "weight_decay": weight_decay, "seed": seed,
        "in_hw": in_hw, "in_ch": in_ch, "num_classes": num_classes,
        "model_kwargs": model_kwargs or {}, "tags": list(tags),
    }


MNIST = dict(in_hw=28, in_ch=1, num_classes=10)
C10 = dict(in_hw=32, in_ch=3, num_classes=10)
IMG = dict(in_hw=64, in_ch=3, num_classes=20)


def build_registry():
    cfgs = []

    # ---- default set ---------------------------------------------------------
    cfgs += [
        # quickstart: tiny MLP on digits, FleXOR 0.8 b/w
        _cfg("quickstart_mlp", "mlp", _flexor(1, 8, 10), batch=64,
             optimizer="adam", weight_decay=0.0,
             model_kwargs={"d_in": 784, "hidden": [128, 64]},
             tags=("default",), **MNIST),
        # e2e driver: ResNet-14 (~170k params) on shapes32, FleXOR 0.8 b/w
        _cfg("e2e_resnet14_f08", "resnet14", _flexor(1, 8, 10), batch=64,
             tags=("default", "e2e"), **C10),
        # pallas-kernel-enabled twin of the quickstart (L1 on the train path)
        _cfg("quickstart_mlp_pallas", "mlp",
             _flexor(1, 8, 10, use_pallas=True), batch=64,
             optimizer="adam", weight_decay=0.0,
             model_kwargs={"d_in": 784, "hidden": [128, 64]},
             tags=("default",), **MNIST),
        # FP reference for the e2e model
        _cfg("e2e_resnet14_fp", "resnet14", {"kind": "fp"}, batch=64,
             tags=("default", "e2e"), **C10),
    ]

    # ---- Fig. 4 / Fig. 12: LeNet-5 on digits, random vs N_tap=2 M⊕ -----------
    for n_out, n_in in [(10, 4), (10, 6), (10, 8), (20, 8), (20, 12), (20, 16)]:
        for tap_tag, n_tap in [("rand", None), ("tap2", 2)]:
            bw = n_in / n_out
            cfgs.append(_cfg(
                f"fig4_lenet_{tap_tag}_ni{n_in}_no{n_out}", "lenet5",
                _flexor(1, n_in, n_out, n_tap=n_tap), batch=50,
                optimizer="adam", weight_decay=0.0,
                model_kwargs={"width_mult": 0.25},
                tags=("full", "fig4") + (("fig12",) if n_tap else ()),
                **MNIST))

    # ---- Fig. 5: XOR training method ablation (0.8 b/w, resnet8) --------------
    for mode in ["flexor", "ste", "analog"]:
        cfgs.append(_cfg(f"fig5_{mode}", "resnet8",
                         _flexor(1, 8, 10, mode=mode), batch=64,
                         tags=("full", "fig5"), **C10))
    cfgs.append(_cfg("fig5_exactgrad", "resnet8",
                     _flexor(1, 8, 10, grad="exact"), batch=64,
                     tags=("full", "fig5"), **C10))

    # ---- Fig. 6: S_tanh sweep reuses fig5_flexor (runtime scalar) -------------

    # ---- Fig. 15 ablations: weight-decay off (LR/S_tanh are runtime scalars,
    # weight decay is baked into the train graph, so it needs its own config)
    cfgs.append(_cfg("fig15_nowd", "resnet8", _flexor(1, 8, 10),
                     batch=64, weight_decay=0.0, tags=("full", "fig15"), **C10))

    # ---- Fig. 7 / Table 1 / Table 5: q, N_in, N_out sweeps on resnet8/14 ------
    for n_in in [4, 6, 8, 10, 12, 16, 20]:
        if n_in <= 20:
            cfgs.append(_cfg(f"sweep_q1_ni{n_in}_no20", "resnet8",
                             _flexor(1, n_in, 20), batch=64,
                             tags=("full", "fig7", "table1"), **C10))
    for n_in in [5, 6, 7, 8, 9, 10]:
        cfgs.append(_cfg(f"sweep_q1_ni{n_in}_no10", "resnet8",
                         _flexor(1, n_in, 10), batch=64,
                         tags=("full", "fig7", "table5"), **C10))
    for n_in in [6, 7, 8, 9, 10]:      # Table 6 (q=2, N_out=10)
        cfgs.append(_cfg(f"sweep_q2_ni{n_in}_no10", "resnet8",
                         _flexor(2, n_in, 10), batch=64,
                         tags=("full", "fig16", "table6"), **C10))
    for n_in in [4, 8, 12, 16, 20]:    # Table 6 (q=2, N_out=20)
        cfgs.append(_cfg(f"sweep_q2_ni{n_in}_no20", "resnet8",
                         _flexor(2, n_in, 20), batch=64,
                         tags=("full", "fig7", "fig16", "table6"), **C10))

    # ---- Table 1 baselines on resnet8 + resnet14 -------------------------------
    for model, mtag in [("resnet8", "r8"), ("resnet14", "r14")]:
        for kind in ["fp", "bwn", "binaryrelax", "ternary", "dsq"]:
            cfgs.append(_cfg(f"base_{mtag}_{kind}", model, {"kind": kind},
                             batch=64, tags=("full", "table1", "table6"),
                             **C10))
        for bw_tag, (q, n_in, n_out) in [("10", (1, 10, 10)), ("08", (1, 8, 10)),
                                         ("06", (1, 12, 20)), ("04", (1, 8, 20))]:
            cfgs.append(_cfg(f"t1_{mtag}_f{bw_tag}", model,
                             _flexor(q, n_in, n_out), batch=64,
                             tags=("full", "table1"), **C10))

    # ---- Table 2: mixed sub-1-bit N_in per layer group (resnet8: 3 stages) ----
    # groups address quantized-layer indices; resnet8 has 7 quantized convs:
    # stage1: 0-1, stage2: 2-4 (incl. downsample), stage3: 5-7
    def groups3(ni1, ni2, ni3):
        return [{"layers": list(range(0, 2)), "n_in": ni1},
                {"layers": list(range(2, 5)), "n_in": ni2},
                {"layers": list(range(5, 8)), "n_in": ni3}]
    for tag, (a, b, c) in [("19_19_8", (19, 19, 8)), ("16_16_8", (16, 16, 8)),
                           ("19_16_7", (19, 16, 7)), ("12_12_12", (12, 12, 12))]:
        cfgs.append(_cfg(f"t2_mixed_{tag}", "resnet8",
                         _flexor(1, 12, 20, groups=groups3(a, b, c)),
                         batch=64, tags=("full", "table2"), **C10))

    # ---- Fig. 8 / Table 3 / Table 7: ImageNet-sub on resnet10img ---------------
    for tag, (q, n_in, n_out) in [("f08", (1, 16, 20)), ("f06", (1, 12, 20)),
                                  ("q2_08", (2, 8, 20)), ("q2_16", (2, 16, 20))]:
        cfgs.append(_cfg(f"t3_img_{tag}", "resnet10img",
                         _flexor(q, n_in, n_out), batch=64,
                         tags=("full", "fig8", "table3", "table7"), **IMG))
    # mixed 0.63 b/w analogue: 4 stage groups with decreasing N_in
    # resnet10img quantized convs: s1:0-1, s2:2-4, s3:5-7, s4:8-10
    cfgs.append(_cfg("t3_img_mixed", "resnet10img",
                     _flexor(1, 12, 20, groups=[
                         {"layers": [0, 1], "n_in": 18},
                         {"layers": [2, 3, 4], "n_in": 16},
                         {"layers": [5, 6, 7], "n_in": 14},
                         {"layers": [8, 9, 10], "n_in": 12}]),
                     batch=64, tags=("full", "fig8", "table3"), **IMG))
    for kind in ["fp", "bwn", "binaryrelax", "ternary"]:
        cfgs.append(_cfg(f"t3_img_{kind}", "resnet10img", {"kind": kind},
                         batch=64, tags=("full", "table3", "table7"), **IMG))

    return {c["name"]: c for c in cfgs}


REGISTRY = build_registry()


def select(set_name: str = "default", only: list[str] | None = None):
    if only:
        missing = [n for n in only if n not in REGISTRY]
        if missing:
            raise KeyError(f"unknown configs: {missing}")
        return [REGISTRY[n] for n in only]
    if set_name == "all":
        return list(REGISTRY.values())
    return [c for c in REGISTRY.values() if set_name in c["tags"]
            or (set_name == "full" and "default" in c["tags"])]
