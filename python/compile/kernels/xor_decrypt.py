"""L1 Pallas kernel: GF(2) XOR-network decrypt in the ±1 domain.

The inference hot spot of FleXOR (paper Fig. 1-3): stored encrypted bits
x ∈ {-1,+1}^{slices×N_in} are decrypted to quantized bits
y ∈ {-1,+1}^{slices×N_out} through the shared matrix M⊕.

TPU-shaped formulation (DESIGN.md §Hardware-Adaptation): instead of per-tap
gather-products (the GPU/ASIC reading), we compute

    negcount = 1[x<0] @ M⊕ᵀ              (an (S_TILE×N_in)·(N_in×N_out)
                                          matmul — MXU work)
    y        = 1 - 2·((negcount + ntap - 1) mod 2)   (VPU elementwise)

The grid tiles the slice axis; M⊕ is tiny (N_out·N_in ≤ 1024 entries) and is
resident in VMEM for every grid step (BlockSpec index None).  VMEM per step =
S_TILE·(N_in+N_out)·4B + |M⊕| ≈ 130 KiB at the default S_TILE=512 — far under
the ~16 MiB VMEM budget, so the schedule is bandwidth-bound as expected for a
decompression kernel.

interpret=True everywhere: the CPU PJRT client cannot run Mosaic
custom-calls; on a real TPU the same BlockSpecs compile unchanged.
"""

from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

S_TILE = 512  # slices per grid step


def _kernel(x_ref, mt_ref, ntap_ref, o_ref):
    x = x_ref[...]                       # (S_TILE, N_in) ±1
    mt = mt_ref[...]                     # (N_in, N_out) {0,1}
    neg = (1.0 - x) * 0.5
    negcount = jnp.dot(neg, mt, preferred_element_type=jnp.float32)
    par = jnp.mod(negcount + ntap_ref[...] - 1.0, 2.0)
    o_ref[...] = 1.0 - 2.0 * par


@functools.partial(jax.jit, static_argnames=("m_tuple",))
def _run(x_sign: jnp.ndarray, m_tuple) -> jnp.ndarray:
    m = np.asarray(m_tuple, dtype=np.float32)
    n_out, n_in = m.shape
    slices = x_sign.shape[0]
    padded = -(-slices // S_TILE) * S_TILE
    xp = jnp.pad(x_sign, ((0, padded - slices), (0, 0)), constant_values=1.0)
    mt = jnp.asarray(m.T)                                  # (N_in, N_out)
    ntap = jnp.asarray(m.sum(axis=1, keepdims=True).T)     # (1, N_out)
    out = pl.pallas_call(
        _kernel,
        grid=(padded // S_TILE,),
        in_specs=[
            pl.BlockSpec((S_TILE, n_in), lambda i: (i, 0)),
            pl.BlockSpec((n_in, n_out), lambda i: (0, 0)),
            pl.BlockSpec((1, n_out), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((S_TILE, n_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded, n_out), jnp.float32),
        interpret=True,
    )(xp, mt, ntap)
    return out[:slices]


def xor_decrypt(x_sign: jnp.ndarray, m: np.ndarray) -> jnp.ndarray:
    """Decrypt ±1 stored bits through M⊕.  See module docstring.

    x_sign: (slices, N_in) ∈ {-1,+1};  m: (N_out, N_in) ∈ {0,1}.
    Returns (slices, N_out) ∈ {-1,+1}.
    """
    m = np.asarray(m, dtype=np.int8)
    return _run(x_sign.astype(jnp.float32), tuple(map(tuple, m.tolist())))
