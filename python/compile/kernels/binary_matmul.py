"""L1 Pallas kernel: binary-code GEMM  out = Σ_i α_i (A @ B_i).

The paper's compute claim (Fig. 1): with q-bit binary codes the dot product
needs q floating multiplies instead of v —  Σ_i α_i Σ_j a_j b_{i,j}.

TPU mapping (DESIGN.md §Hardware-Adaptation): each ±1 bit-plane B_i is a
dense matrix the MXU multiplies at full rate, so the kernel is q MXU matmuls
per (row-tile × col-tile) grid cell, with the α_i scaling and plane
accumulation fused in VPU registers before a single store — the TPU-native
reading of "q multiplies instead of v", with no dequantized weight tensor
ever materialized in HBM.

Grid: (N/N_TILE, C/C_TILE); the V (reduction) axis stays resident in VMEM —
our layer sizes put V·(N_TILE+C_TILE)·4B well under VMEM; larger V would add
a third grid axis with an accumulator, noted in DESIGN.md §Perf.
"""

from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

N_TILE = 128
C_TILE = 128


def _kernel(a_ref, bits_ref, alpha_ref, o_ref):
    a = a_ref[...]                           # (N_TILE, V)
    q = bits_ref.shape[0]
    acc = jnp.zeros((a.shape[0], o_ref.shape[1]), jnp.float32)
    for i in range(q):                       # q is static and small (1..3)
        plane = jnp.dot(a, bits_ref[i], preferred_element_type=jnp.float32)
        acc = acc + plane * alpha_ref[i]     # (N_TILE, C_TILE) * (1, C_TILE)
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=())
def _run(a, bits, alpha):
    n, v = a.shape
    q, _, c = bits.shape
    np_ = -(-n // N_TILE) * N_TILE
    cp = -(-c // C_TILE) * C_TILE
    ap = jnp.pad(a, ((0, np_ - n), (0, 0)))
    bp = jnp.pad(bits, ((0, 0), (0, 0), (0, cp - c)))
    alp = jnp.pad(alpha, ((0, 0), (0, cp - c))).reshape(q, 1, cp)
    out = pl.pallas_call(
        _kernel,
        grid=(np_ // N_TILE, cp // C_TILE),
        in_specs=[
            pl.BlockSpec((N_TILE, v), lambda i, j: (i, 0)),
            pl.BlockSpec((q, v, C_TILE), lambda i, j: (0, 0, j)),
            pl.BlockSpec((q, 1, C_TILE), lambda i, j: (0, 0, j)),
        ],
        out_specs=pl.BlockSpec((N_TILE, C_TILE), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((np_, cp), jnp.float32),
        interpret=True,
    )(ap, bp, alp)
    return out[:n, :c]


def binary_matmul(a: jnp.ndarray, bits: jnp.ndarray,
                  alpha: jnp.ndarray) -> jnp.ndarray:
    """out[n,c] = Σ_i alpha[i,c] Σ_v a[n,v] bits[i,v,c].

    a: (N, V) f32;  bits: (q, V, C) ∈ {-1,+1} f32;  alpha: (q, C) f32.
    """
    return _run(a.astype(jnp.float32), bits.astype(jnp.float32),
                alpha.astype(jnp.float32))
