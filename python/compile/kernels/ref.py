"""Pure-jnp reference oracles for every Pallas kernel.

These are the correctness ground truth: pytest (python/tests/) sweeps shapes
and dtypes with hypothesis and asserts the Pallas kernels (interpret=True)
match these to tight tolerances.  They are also the jnp fallback path used
by quant.flexor_weight when ``use_pallas=False``.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp


def xor_decrypt_ref(x_sign: jnp.ndarray, m: np.ndarray) -> jnp.ndarray:
    """Boolean GF(2) decrypt in the ±1 domain.

    x_sign: (slices, N_in) ∈ {-1,+1};  m: (N_out, N_in) ∈ {0,1}.
    Returns (slices, N_out) ∈ {-1,+1}:
        y[s,r] = (-1)^(ntap_r-1) ∏_{j: m[r,j]=1} x_sign[s,j]
    """
    mf = jnp.asarray(m, dtype=x_sign.dtype)
    neg = (1.0 - x_sign) * 0.5
    negcount = neg @ mf.T
    ntap = mf.sum(axis=1)
    return 1.0 - 2.0 * jnp.mod(negcount + ntap - 1.0, 2.0)


def flexor_fwd_ref(x: jnp.ndarray, m: np.ndarray) -> jnp.ndarray:
    """Training-path decrypt forward: sign() then Boolean decrypt (Eq. 4)."""
    return xor_decrypt_ref(jnp.sign(jnp.where(x == 0, 1e-12, x)), m)


def flexor_bwd_ref(x: jnp.ndarray, s_tanh, m: np.ndarray,
                   g: jnp.ndarray) -> jnp.ndarray:
    """Eq. (6) cotangent wrt encrypted weights x given output cotangent g.

    dL/dx[s,i] = S (1-tanh²(x_i S)) sign(x_i) Σ_r m[r,i] g[s,r] y[s,r]
    """
    y = flexor_fwd_ref(x, m)
    t = jnp.tanh(x * s_tanh)
    sgn = jnp.sign(jnp.where(x == 0, 1e-12, x))
    return ((g * y) @ jnp.asarray(m, x.dtype)) * s_tanh * (1.0 - t * t) * sgn


def binary_matmul_ref(a: jnp.ndarray, bits: jnp.ndarray,
                      alpha: jnp.ndarray) -> jnp.ndarray:
    """Binary-code GEMM:  out[n,c] = Σ_i alpha[i,c] · Σ_v a[n,v] bits[i,v,c].

    a: (N, V) activations;  bits: (q, V, C) ∈ {-1,+1};  alpha: (q, C).
    """
    planes = jnp.einsum("nv,qvc->qnc", a, bits)
    return jnp.einsum("qnc,qc->nc", planes, alpha)


def decrypt_matmul_ref(a: jnp.ndarray, x_sign: jnp.ndarray, m: np.ndarray,
                       alpha: jnp.ndarray, v: int, c: int) -> jnp.ndarray:
    """Fused inference path: decrypt q planes then binary-code GEMM.

    x_sign: (q, slices, N_in) stored encrypted bits (±1).
    Returns (N, c) = Σ_i alpha_i (a @ B_i) with B_i the decrypt of plane i
    cropped/reshaped to (v, c).
    """
    q = x_sign.shape[0]
    planes = []
    for i in range(q):
        bits = xor_decrypt_ref(x_sign[i], m).reshape(-1)[: v * c].reshape(v, c)
        planes.append(bits)
    bits = jnp.stack(planes)                      # (q, v, c)
    return binary_matmul_ref(a, bits, alpha)
