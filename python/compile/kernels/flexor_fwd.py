"""L1 Pallas kernels: trainable FleXOR decrypt (forward + Eq. 6 backward).

The training-path twin of xor_decrypt: forward takes *real* encrypted
weights, signs them and decrypts (Eq. 2/4); backward applies the paper's
simplified custom gradient (Eq. 6), which reduces to a single matmul against
M⊕ plus elementwise tanh' scaling (derivation in flexor.py):

    dL/dx[s,i] = S·(1-tanh²(x_i S))·sign(x_i) · Σ_r M[r,i]·g[s,r]·y[s,r]

Both directions are Pallas kernels gridded over slice tiles; the contraction
(g·y) @ M⊕ is MXU work, everything else VPU elementwise.  The pair is wired
into jax.custom_vjp so the L2 model just calls ``decrypt_train`` and autodiff
sees the paper's gradient.

Ablation modes ('ste', 'analog', grad='exact') route to the jnp
implementations in flexor.py — they exist for Fig. 5/appendix experiments,
not the hot path.
"""

from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import flexor as _flexor
from .xor_decrypt import S_TILE


def _sgn(x):
    return jnp.sign(jnp.where(x == 0, 1e-12, x))


def _fwd_kernel(x_ref, mt_ref, ntap_ref, o_ref):
    x = _sgn(x_ref[...])
    neg = (1.0 - x) * 0.5
    negcount = jnp.dot(neg, mt_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = 1.0 - 2.0 * jnp.mod(negcount + ntap_ref[...] - 1.0, 2.0)


def _bwd_kernel(x_ref, y_ref, g_ref, m_ref, s_ref, o_ref):
    x = x_ref[...]                       # (S_TILE, N_in)
    s = s_ref[0, 0]
    t = jnp.tanh(x * s)
    gy = g_ref[...] * y_ref[...]         # (S_TILE, N_out)
    acc = jnp.dot(gy, m_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = acc * s * (1.0 - t * t) * _sgn(x)


def _pad(a, tile):
    n = a.shape[0]
    p = -(-n // tile) * tile
    return jnp.pad(a, ((0, p - n), (0, 0))), n, p


@functools.partial(jax.jit, static_argnames=("m_tuple",))
def _fwd_run(x, m_tuple):
    m = np.asarray(m_tuple, dtype=np.float32)
    n_out, n_in = m.shape
    xp, n, p = _pad(x, S_TILE)
    out = pl.pallas_call(
        _fwd_kernel,
        grid=(p // S_TILE,),
        in_specs=[
            pl.BlockSpec((S_TILE, n_in), lambda i: (i, 0)),
            pl.BlockSpec((n_in, n_out), lambda i: (0, 0)),
            pl.BlockSpec((1, n_out), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((S_TILE, n_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((p, n_out), jnp.float32),
        interpret=True,
    )(xp, jnp.asarray(m.T), jnp.asarray(m.sum(axis=1, keepdims=True).T))
    return out[:n]


@functools.partial(jax.jit, static_argnames=("m_tuple",))
def _bwd_run(x, y, g, s_tanh, m_tuple):
    m = np.asarray(m_tuple, dtype=np.float32)
    n_out, n_in = m.shape
    xp, n, p = _pad(x, S_TILE)
    yp, _, _ = _pad(y, S_TILE)
    gp, _, _ = _pad(g, S_TILE)
    s2d = jnp.reshape(s_tanh.astype(jnp.float32), (1, 1))
    out = pl.pallas_call(
        _bwd_kernel,
        grid=(p // S_TILE,),
        in_specs=[
            pl.BlockSpec((S_TILE, n_in), lambda i: (i, 0)),
            pl.BlockSpec((S_TILE, n_out), lambda i: (i, 0)),
            pl.BlockSpec((S_TILE, n_out), lambda i: (i, 0)),
            pl.BlockSpec((n_out, n_in), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((S_TILE, n_in), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((p, n_in), jnp.float32),
        interpret=True,
    )(xp, yp, gp, jnp.asarray(m), s2d)
    return out[:n]


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _decrypt_pallas(x, s_tanh, m_tuple):
    return _fwd_run(x, m_tuple)


def _vjp_fwd(x, s_tanh, m_tuple):
    y = _fwd_run(x, m_tuple)
    return y, (x, s_tanh, y)


def _vjp_bwd(m_tuple, res, g):
    x, s_tanh, y = res
    dx = _bwd_run(x, y, g, jnp.asarray(s_tanh), m_tuple)
    return dx, jnp.zeros_like(s_tanh)


_decrypt_pallas.defvjp(_vjp_fwd, _vjp_bwd)


def decrypt_train(x: jnp.ndarray, s_tanh, m: np.ndarray, *,
                  mode: str = "flexor", grad: str = "approx") -> jnp.ndarray:
    """Trainable decrypt; Pallas hot path for the paper's (flexor, Eq. 6)
    configuration, jnp fallbacks for the ablation modes."""
    if mode == "flexor" and grad == "approx":
        m8 = np.asarray(m, dtype=np.int8)
        return _decrypt_pallas(x.astype(jnp.float32),
                               jnp.asarray(s_tanh, dtype=jnp.float32),
                               tuple(map(tuple, m8.tolist())))
    return _flexor.flexor_decrypt(x, s_tanh, m, mode=mode, grad=grad)
