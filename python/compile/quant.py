"""Binary-code quantizers: FleXOR (fractional bits) and the paper's baselines.

A binary-coding-based quantizer represents a weight tensor W as
``Σ_{i=1}^q α_i · b_i`` with per-output-channel scaling factors α ∈ ℝ^{C_out}
and bit-planes b_i ∈ {-1,+1} (paper §1).

FleXOR stores, per bit-plane, a real *encrypted* tensor of shape
``(slices, N_in)`` and recovers the plane's ±1 bits through the shared
XOR-gate network M⊕ (flexor.flexor_decrypt).  Rate = q·N_in/N_out b/w.

Baselines (Table 1 / 3 / 6 / 7 comparators) quantize latent full-precision
weights directly:

  * BWN          — b = sign(w), α = E|w| per out-channel, STE backward. [22]
  * BinaryRelax  — relaxed mixture (λ·sign(w)+w)/(λ+1) with λ growing, so the
                   projection anneals from identity to sign. [28]
  * TWN/TTQ-like — ternary {-α,0,+α} with threshold 0.7·E|w|, STE. [18,30]
  * DSQ-like     — soft tanh-cell quantizer with STE-corrected forward. [7]

All quantizers share the interface

    qw = quantize_<name>(params, ctx) -> weight tensor of `shape`

so the model code is quantizer-agnostic (models/*.py call through a
Quantizer spec), and each trains end-to-end inside the same lowered HLO.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from . import flexor

__all__ = [
    "FlexorSpec", "init_flexor_weight", "flexor_weight",
    "init_bwn_weight", "bwn_weight",
    "init_binaryrelax_weight", "binaryrelax_weight",
    "init_ternary_weight", "ternary_weight",
    "init_dsq_weight", "dsq_weight",
    "init_fp_weight", "fp_weight",
]


# ---------------------------------------------------------------------------
# FleXOR quantized weight
# ---------------------------------------------------------------------------

class FlexorSpec:
    """Static (trace-time) description of one layer's FleXOR config.

    One spec may be shared by many layers ("M⊕ is shared"); Table 2's
    mixed-precision experiments give different specs to layer groups.
    """

    def __init__(self, q: int, n_in: int, n_out: int, *,
                 n_tap: int | None = 2, seed: int = 7,
                 mode: str = "flexor", grad: str = "approx"):
        self.q = q
        self.n_in = n_in
        self.n_out = n_out
        self.n_tap = n_tap
        self.mode = mode
        self.grad = grad
        # one independent M⊕ per bit-plane (paper: "for q>1, different M⊕
        # configurations are constructed and then shared across all layers")
        self.mxor = [flexor.make_mxor(n_out, n_in, n_tap=n_tap, seed=seed + i)
                     for i in range(q)]

    @property
    def bits_per_weight(self) -> float:
        return flexor.bits_per_weight(self.q, self.n_in, self.n_out)

    def storage_bits(self, n_weights: int) -> int:
        """Encrypted bits stored for a tensor of n_weights (per Alg. 1)."""
        return self.q * flexor.num_slices(n_weights, self.n_out) * self.n_in


def init_flexor_weight(key, shape, spec: FlexorSpec, alpha_init: float = 0.2):
    """Parameters for one FleXOR-quantized weight tensor.

    Encrypted weights ~ N(0, 0.001²) (paper §3); α initialised to 0.2 per
    output channel (paper §3/§4).  Output channel = last axis of `shape`
    (weights are stored (k,k,Cin,Cout) / (in,out)).
    """
    n_weights = int(np.prod(shape))
    c_out = shape[-1]
    slices = flexor.num_slices(n_weights, spec.n_out)
    w_enc = jax.random.normal(key, (spec.q, slices, spec.n_in)) * 1e-3
    alpha = jnp.full((spec.q, c_out), alpha_init, dtype=jnp.float32)
    return {"w_enc": w_enc, "alpha": alpha}


def flexor_weight(p, shape, spec: FlexorSpec, s_tanh, *, use_pallas: bool = False):
    """Reconstruct the quantized weight tensor from encrypted params.

    Decrypt each plane through its M⊕ (trainable path), crop the padding,
    reshape to `shape`, scale by per-out-channel α, and sum the q planes.
    """
    n_weights = int(np.prod(shape))
    c_out = shape[-1]
    planes = []
    for i in range(spec.q):
        if use_pallas:
            from .kernels import flexor_fwd as _k
            bits = _k.decrypt_train(p["w_enc"][i], s_tanh, spec.mxor[i],
                                    mode=spec.mode, grad=spec.grad)
        else:
            bits = flexor.flexor_decrypt(p["w_enc"][i], s_tanh, spec.mxor[i],
                                         mode=spec.mode, grad=spec.grad)
        flat = bits.reshape(-1)[:n_weights]
        wq = flat.reshape(shape)
        planes.append(wq * p["alpha"][i].reshape((1,) * (len(shape) - 1) + (c_out,)))
    return sum(planes)


# ---------------------------------------------------------------------------
# STE for baselines
# ---------------------------------------------------------------------------

@jax.custom_vjp
def _ste_sign(w):
    return jnp.sign(jnp.where(w == 0, 1e-12, w))


def _ste_sign_fwd(w):
    return _ste_sign(w), w


def _ste_sign_bwd(w, g):
    # BinaryConnect-style clipped STE: pass gradient where |w| <= 1
    return (g * (jnp.abs(w) <= 1.0),)


_ste_sign.defvjp(_ste_sign_fwd, _ste_sign_bwd)


@jax.custom_vjp
def _ste_through(target, w):
    """Forward `target`, backward to `w` (identity)."""
    return target


def _ste_through_fwd(target, w):
    return target, None


def _ste_through_bwd(_, g):
    return None, g


_ste_through.defvjp(_ste_through_fwd, _ste_through_bwd)


def _per_channel_mean_abs(w):
    """E|w| per output channel (last axis), broadcastable to w."""
    flat = jnp.abs(w).reshape(-1, w.shape[-1])
    return flat.mean(axis=0).reshape((1,) * (w.ndim - 1) + (w.shape[-1],))


# ---------------------------------------------------------------------------
# Full precision (the FP rows of every table)
# ---------------------------------------------------------------------------

def init_fp_weight(key, shape, gain: float = 1.0):
    fan_in = int(np.prod(shape[:-1]))
    std = gain * (2.0 / fan_in) ** 0.5  # He init
    return {"w": jax.random.normal(key, shape) * std}


def fp_weight(p, shape=None, ctx=None):
    return p["w"]


# --- BWN [22] ----------------------------------------------------------------

def init_bwn_weight(key, shape):
    return init_fp_weight(key, shape)


def bwn_weight(p, shape=None, ctx=None):
    w = p["w"]
    alpha = _per_channel_mean_abs(w)
    return _ste_sign(w) * alpha


# --- BinaryRelax [28] ---------------------------------------------------------
# W_relaxed = (λ·α·sign(w) + w) / (λ + 1); λ = relax_lambda grows during
# training (scheduled by the coordinator via a scalar input); λ→∞ recovers BWN.

def init_binaryrelax_weight(key, shape):
    return init_fp_weight(key, shape)


def binaryrelax_weight(p, relax_lambda, shape=None, ctx=None):
    w = p["w"]
    alpha = _per_channel_mean_abs(w)
    hard = jnp.sign(jnp.where(w == 0, 1e-12, w)) * alpha
    return (relax_lambda * hard + w) / (relax_lambda + 1.0)


# --- Ternary (TWN [18] threshold rule, trained scales like TTQ [30]) -----------

def init_ternary_weight(key, shape):
    p = init_fp_weight(key, shape)
    p["wp"] = jnp.ones((shape[-1],)) * 0.2
    p["wn"] = jnp.ones((shape[-1],)) * 0.2
    return p


def ternary_weight(p, shape=None, ctx=None):
    w = p["w"]
    thr = 0.7 * _per_channel_mean_abs(w)
    pos = (w > thr).astype(w.dtype)
    neg = (w < -thr).astype(w.dtype)
    bshape = (1,) * (w.ndim - 1) + (w.shape[-1],)
    tern = pos * p["wp"].reshape(bshape) - neg * p["wn"].reshape(bshape)
    # additive STE: forward is `tern`; gradient flows identically to the
    # latent w (TWN) while wp/wn keep their true multiplicative gradients
    # (TTQ's trained scales).
    return tern + w - jax.lax.stop_gradient(w)


# --- DSQ-like [7] --------------------------------------------------------------
# 1-bit differentiable soft quantization: soft cell φ(w) = tanh(w·k)/tanh(k)
# with trainable steepness k, hard sign forwarded via STE on φ.

def init_dsq_weight(key, shape):
    p = init_fp_weight(key, shape)
    p["k"] = jnp.asarray(2.0)
    return p


def dsq_weight(p, shape=None, ctx=None):
    w = p["w"]
    k = jnp.maximum(p["k"], 0.5)
    alpha = _per_channel_mean_abs(w)
    soft = jnp.tanh(w * k) / jnp.tanh(k)
    hard = jnp.sign(jnp.where(soft == 0, 1e-12, soft))
    return _ste_through(hard, soft) * alpha


# ---------------------------------------------------------------------------
# Quantizer dispatch — what models are parameterized over
# ---------------------------------------------------------------------------

class Quantizer:
    """Uniform interface the models call for every *quantized* layer.

    kind ∈ {'fp','flexor','bwn','binaryrelax','ternary','dsq'}.

    For FleXOR, ``specs`` maps a layer index to its FlexorSpec (mixed
    sub-1-bit precision, Table 2); ``spec`` is the shared default.  The
    training context ``ctx`` carries the scheduled scalars (s_tanh,
    relax_lambda) the Rust coordinator feeds to the HLO each step.
    """

    KINDS = ("fp", "flexor", "bwn", "binaryrelax", "ternary", "dsq")

    def __init__(self, kind: str = "fp", spec: FlexorSpec | None = None,
                 specs: dict | None = None, use_pallas: bool = False):
        if kind not in self.KINDS:
            raise ValueError(f"unknown quantizer kind {kind!r}")
        if kind == "flexor" and spec is None and not specs:
            raise ValueError("flexor quantizer needs a FlexorSpec")
        self.kind = kind
        self.spec = spec
        self.specs = specs or {}
        self.use_pallas = use_pallas

    def spec_for(self, layer_idx: int) -> FlexorSpec:
        return self.specs.get(layer_idx, self.spec)

    def init(self, key, shape, layer_idx: int = 0):
        if self.kind == "fp":
            return init_fp_weight(key, shape)
        if self.kind == "flexor":
            return init_flexor_weight(key, shape, self.spec_for(layer_idx))
        if self.kind == "bwn":
            return init_bwn_weight(key, shape)
        if self.kind == "binaryrelax":
            return init_binaryrelax_weight(key, shape)
        if self.kind == "ternary":
            return init_ternary_weight(key, shape)
        if self.kind == "dsq":
            return init_dsq_weight(key, shape)
        raise AssertionError(self.kind)

    def __call__(self, p, shape, ctx, layer_idx: int = 0):
        """Produce the layer's effective weight tensor."""
        if self.kind == "fp":
            return fp_weight(p)
        if self.kind == "flexor":
            return flexor_weight(p, shape, self.spec_for(layer_idx),
                                 ctx["s_tanh"], use_pallas=self.use_pallas)
        if self.kind == "bwn":
            return bwn_weight(p)
        if self.kind == "binaryrelax":
            return binaryrelax_weight(p, ctx["relax_lambda"])
        if self.kind == "ternary":
            return ternary_weight(p)
        if self.kind == "dsq":
            return dsq_weight(p)
        raise AssertionError(self.kind)

    def storage_bits(self, n_weights: int, layer_idx: int = 0) -> int:
        """Stored bits for a quantized tensor (excludes α / FP layers)."""
        if self.kind == "fp":
            return 32 * n_weights
        if self.kind == "flexor":
            return self.spec_for(layer_idx).storage_bits(n_weights)
        if self.kind == "ternary":
            return 2 * n_weights
        return n_weights  # 1-bit codes
