//! Fig. 6 / Figs. 13-14 reproduction: the effect of S_tanh on accuracy and
//! on the distribution of encrypted weights. S_tanh is a *runtime scalar*
//! input to the train HLO, so one artifact serves the whole sweep.
//!
//! Paper claims:
//!   * large S_tanh clusters encrypted weights away from zero (bimodal);
//!   * accuracy peaks at a moderate S_tanh (too small = loose clustering,
//!     too large = can't fine-tune).
//!
//! ```bash
//! cargo run --release --example fig6_stanh -- --hist
//! ```

use anyhow::Result;

use flexor::coordinator::experiments::{print_table, run_all, scaled, RunSpec};
use flexor::coordinator::{MetricsSink, Schedule, TrainSession};
use flexor::data;
use flexor::runtime::{Manifest, Runtime};
use flexor::substrate::argparse::Args;

fn main() -> Result<()> {
    let a = Args::new("fig6_stanh", "Fig. 6: S_tanh sweep + weight distributions")
        .flag("scale", "step-count scale factor", Some("1.0"))
        .flag("steps", "base steps per run", Some("500"))
        .flag("seeds", "seeds per point", Some("2"))
        .switch("hist", "print encrypted-weight histograms (Figs. 13-14)")
        .parse();
    let steps = scaled(a.get_usize("steps"), a.get_f32("scale"));
    let seeds: Vec<u64> = (0..a.get_usize("seeds") as u64).collect();

    let rt = Runtime::cpu()?;
    let man = Manifest::load(std::path::Path::new(flexor::ARTIFACTS_DIR))?;

    let mut specs = Vec::new();
    for s_tanh in [1.0f32, 10.0, 50.0, 100.0] {
        let sched = Schedule {
            s_tanh_start: s_tanh,
            s_tanh_base: s_tanh,
            s_tanh_decay_mult: 1.0,
            ..Schedule::cifar(0.05, 0.5, vec![3.0, 4.0], 100)
        };
        specs.push(
            RunSpec::new(&format!("S_tanh = {s_tanh}"), "fig5_flexor", "shapes32", steps)
                .schedule(sched)
                .seeds(seeds.clone())
                .eval_every((steps / 8).max(1)),
        );
    }
    let outs = run_all(&rt, &man, &specs)?;
    print_table("Fig. 6 — S_tanh sweep (ResNet-8, 0.8 b/w)", &outs);

    if a.get_bool("hist") {
        // Figs. 13/14: end-of-training encrypted weight distributions per
        // S_tanh — retrain one seed per point and histogram all w_enc.
        println!("\n=== Figs. 13-14 — encrypted-weight distributions ===");
        for s_tanh in [1.0f32, 10.0, 100.0] {
            let sched = Schedule {
                s_tanh_start: s_tanh,
                s_tanh_base: s_tanh,
                s_tanh_decay_mult: 1.0,
                ..Schedule::cifar(0.05, 0.5, vec![3.0, 4.0], 100)
            };
            let mut session = TrainSession::new(&rt, &man, "fig5_flexor")?;
            let ds = data::by_name("shapes32", 0)?;
            let mut sink = MetricsSink::new();
            session.train_loop(ds.as_ref(), &sched, steps, steps, 256, &mut sink)?;
            let h = session.encrypted_weight_histogram(-0.5, 0.5, 21)?;
            println!("\nS_tanh = {s_tanh}  (total {} weights):", h.total());
            println!("{}", h.ascii(48));
        }
    }
    Ok(())
}
