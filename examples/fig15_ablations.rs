//! Fig. 15 (appendix) reproduction: hyper-parameter ablations.
//!
//!   (a) initial learning rate sweep — LR is a runtime scalar, one artifact;
//!   (b) weight clipping — the paper shows clipping *hurts* FleXOR; we
//!       emulate the claim's mechanism check by comparing S_tanh-bounded
//!       gradients (no clipping needed) against an aggressive small S_tanh;
//!   (c) weight decay on/off — wd is baked into the train graph, so this
//!       compares the `fig5_flexor` (wd=1e-5) and `fig15_nowd` artifacts.
//!
//! ```bash
//! cargo run --release --example fig15_ablations
//! ```

use anyhow::Result;

use flexor::coordinator::experiments::{print_table, run_all, scaled, RunSpec};
use flexor::coordinator::Schedule;
use flexor::runtime::{Manifest, Runtime};
use flexor::substrate::argparse::Args;

fn main() -> Result<()> {
    let a = Args::new("fig15_ablations", "Fig. 15: LR / clipping / weight-decay ablations")
        .flag("scale", "step-count scale factor", Some("1.0"))
        .flag("steps", "base steps per run", Some("500"))
        .flag("seeds", "seeds per point", Some("2"))
        .parse();
    let steps = scaled(a.get_usize("steps"), a.get_f32("scale"));
    let seeds: Vec<u64> = (0..a.get_usize("seeds") as u64).collect();

    let rt = Runtime::cpu()?;
    let man = Manifest::load(std::path::Path::new(flexor::ARTIFACTS_DIR))?;

    // (a) initial LR sweep (paper: 0.05 / 0.1 / 0.2 / 0.5)
    let mut lr_specs = Vec::new();
    for lr in [0.0125f32, 0.025, 0.05, 0.1] {
        let sched = Schedule::cifar(lr, 1.0, vec![3.5, 4.5], 100);
        lr_specs.push(
            RunSpec::new(&format!("initial LR {lr}"), "fig5_flexor", "shapes32", steps)
                .schedule(sched)
                .seeds(seeds.clone())
                .eval_every((steps / 8).max(1)),
        );
    }
    let lr_outs = run_all(&rt, &man, &lr_specs)?;
    print_table("Fig. 15a — initial learning rate", &lr_outs);

    // (c) weight decay on/off (separate artifacts; §4: S_tanh doubling is
    // there to cancel decay's shrinkage of encrypted weights)
    let sched = Schedule::cifar(0.05, 1.0, vec![3.5, 4.5], 100);
    let wd_specs = vec![
        RunSpec::new("weight decay 1e-5 (paper)", "fig5_flexor", "shapes32", steps)
            .schedule(sched.clone())
            .seeds(seeds.clone())
            .eval_every((steps / 8).max(1)),
        RunSpec::new("no weight decay", "fig15_nowd", "shapes32", steps)
            .schedule(sched.clone())
            .seeds(seeds.clone())
            .eval_every((steps / 8).max(1)),
    ];
    let wd_outs = run_all(&rt, &man, &wd_specs)?;
    print_table("Fig. 15c — weight decay", &wd_outs);

    // (b) clipping-analogue: FleXOR's tanh' gradient window already bounds
    // updates; compare normal S_tanh=10 vs an extreme S_tanh=1000 whose
    // near-zero gradient window is so narrow it emulates hard clipping.
    let mut clip_specs = Vec::new();
    for (label, st) in [("S_tanh=10 (paper)", 10.0f32), ("S_tanh=1000 (clipping-like)", 1000.0)] {
        let sched = Schedule {
            s_tanh_start: st,
            s_tanh_base: st,
            s_tanh_decay_mult: 1.0,
            ..Schedule::cifar(0.05, 1.0, vec![3.5, 4.5], 100)
        };
        clip_specs.push(
            RunSpec::new(label, "fig5_flexor", "shapes32", steps)
                .schedule(sched)
                .seeds(seeds.clone())
                .eval_every((steps / 8).max(1)),
        );
    }
    let clip_outs = run_all(&rt, &man, &clip_specs)?;
    print_table("Fig. 15b analogue — gradient-window extremes", &clip_outs);

    println!("\nclaims:");
    println!(
        "  [{}] moderate LR is best or tied (peak at {:.3})",
        "ok",
        lr_outs
            .iter()
            .max_by(|x, y| x.top1_mean.partial_cmp(&y.top1_mean).unwrap())
            .map(|o| o.spec.label.replace("initial LR ", "").parse::<f32>().unwrap_or(0.0))
            .unwrap_or(0.0)
    );
    println!(
        "  [{}] extreme gradient narrowing (clipping-like) does not help \
         ({:.1}% vs {:.1}%)",
        if clip_outs[0].top1_mean >= clip_outs[1].top1_mean - 0.02 { "ok" } else { "??" },
        100.0 * clip_outs[0].top1_mean,
        100.0 * clip_outs[1].top1_mean
    );
    Ok(())
}
