//! Table 5 (appendix) reproduction: the N_out=10 rate sweep 1.0 → 0.5
//! bit/weight, with the compression-ratio column computed byte-exactly
//! from the FXR container (encrypted bits + per-channel α, as the paper's
//! footnote specifies).
//!
//! ```bash
//! cargo run --release --example table5_rates -- --scale 1.0
//! ```

use anyhow::Result;

use flexor::coordinator::experiments::{print_table, run_all, scaled, RunSpec};
use flexor::coordinator::{export_fxr, MetricsSink, Schedule, TrainSession};
use flexor::data;
use flexor::runtime::{Manifest, Runtime};
use flexor::substrate::argparse::Args;

fn main() -> Result<()> {
    let a = Args::new("table5_rates", "Table 5: N_out=10 rate sweep + compression ratios")
        .flag("scale", "step-count scale factor", Some("1.0"))
        .flag("steps", "base steps per run", Some("500"))
        .flag("seeds", "seeds per point", Some("2"))
        .parse();
    let steps = scaled(a.get_usize("steps"), a.get_f32("scale"));
    let seeds: Vec<u64> = (0..a.get_usize("seeds") as u64).collect();

    let sched = Schedule::cifar(0.05, 1.0, vec![3.5, 4.5], 100);
    let paper = [(10, 90.21, 29.95), (9, 90.03, 31.82), (8, 89.73, 35.32),
                 (7, 89.88, 39.68), (6, 89.21, 45.27), (5, 88.59, 52.70)];

    let specs: Vec<RunSpec> = paper
        .iter()
        .map(|(ni, acc, _)| {
            RunSpec::new(
                &format!("N_in={ni}, N_out=10 ({:.1} b/w)", *ni as f64 / 10.0),
                &format!("sweep_q1_ni{ni}_no10"),
                "shapes32",
                steps,
            )
            .schedule(sched.clone())
            .seeds(seeds.clone())
            .eval_every((steps / 8).max(1))
            .paper(*acc)
        })
        .collect();

    let rt = Runtime::cpu()?;
    let man = Manifest::load(std::path::Path::new(flexor::ARTIFACTS_DIR))?;
    let outs = run_all(&rt, &man, &specs)?;
    print_table("Table 5 — rate sweep (ResNet-8 on shapes32, N_out=10)", &outs);

    // exact compression ratios from a real exported container per config
    println!(
        "\n{:<28} {:>8} {:>16} {:>18} {:>14}",
        "config", "b/w", "comp (weights)", "comp (w/ alpha)", "paper comp"
    );
    for ((ni, _, paper_comp), o) in paper.iter().zip(&outs) {
        let mut session = TrainSession::new(&rt, &man, &o.spec.artifact)?;
        // no training needed for storage accounting — export at init
        let ds = data::by_name("shapes32", 0)?;
        let mut sink = MetricsSink::new();
        session.train_loop(ds.as_ref(), &sched, 1, 1, 64, &mut sink)?;
        let stats = export_fxr(&session)?.stats();
        println!(
            "{:<28} {:>8.2} {:>15.2}× {:>17.2}× {:>13.2}×",
            format!("N_in={ni}, N_out=10"),
            stats.bits_per_weight,
            stats.compression_ratio_weights_only,
            stats.compression_ratio_with_alpha,
            paper_comp
        );
    }
    println!("\n(note: paper ratios include FP first/last layers in the denominator,");
    println!(" ours count quantized layers only — the *trend* across N_in is the check)");
    Ok(())
}
