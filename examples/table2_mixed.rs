//! Table 2 reproduction: mixed sub-1-bit precision — different N_in per
//! layer group (N_out=20 fixed), compared against a uniform-N_in model of
//! higher average rate.
//!
//! Paper claim: giving large-parameter late stages a *smaller* N_in and
//! small early stages a larger N_in yields equal-or-better accuracy at
//! fewer average bits/weight than the uniform assignment.
//!
//! ```bash
//! cargo run --release --example table2_mixed -- --scale 1.0
//! ```

use anyhow::Result;

use flexor::coordinator::experiments::{print_table, run_all, scaled, RunSpec};
use flexor::coordinator::Schedule;
use flexor::runtime::{Manifest, Runtime};
use flexor::substrate::argparse::Args;

fn main() -> Result<()> {
    let a = Args::new("table2_mixed", "Table 2: mixed sub-1-bit N_in per layer group")
        .flag("scale", "step-count scale factor", Some("1.0"))
        .flag("steps", "base steps per run", Some("500"))
        .flag("seeds", "seeds per point", Some("2"))
        .parse();
    let steps = scaled(a.get_usize("steps"), a.get_f32("scale"));
    let seeds: Vec<u64> = (0..a.get_usize("seeds") as u64).collect();

    let sched = Schedule::cifar(0.05, 1.0, vec![3.5, 4.5], 100);
    let mk = |label: &str, cfg: &str, paper: f64| {
        RunSpec::new(label, cfg, "shapes32", steps)
            .schedule(sched.clone())
            .seeds(seeds.clone())
            .eval_every((steps / 8).max(1))
            .paper(paper)
    };
    let specs = vec![
        mk("uniform N_in=12 (0.60 b/w)", "t2_mixed_12_12_12", 89.16),
        mk("19 / 19 / 8  (≈0.53 b/w)", "t2_mixed_19_19_8", 89.23),
        mk("16 / 16 / 8  (≈0.50 b/w)", "t2_mixed_16_16_8", 89.19),
        mk("19 / 16 / 7  (≈0.47 b/w)", "t2_mixed_19_16_7", 89.29),
    ];

    let rt = Runtime::cpu()?;
    let man = Manifest::load(std::path::Path::new(flexor::ARTIFACTS_DIR))?;
    let outs = run_all(&rt, &man, &specs)?;
    print_table("Table 2 — mixed-precision layer groups (ResNet-8, N_out=20)", &outs);

    println!("\n(avg bits/weight measured from storage accounting:)");
    for o in &outs {
        println!("  {:<30} {:.3} b/w", o.spec.label, o.bits_per_weight);
    }
    let uni = &outs[0];
    let best_mixed = outs[1..]
        .iter()
        .max_by(|x, y| x.top1_mean.partial_cmp(&y.top1_mean).unwrap())
        .unwrap();
    println!("\nclaims:");
    println!(
        "  [{}] a mixed assignment matches the uniform one at fewer bits \
         ({:.1}% @ {:.2} b/w vs uniform {:.1}% @ {:.2} b/w)",
        if best_mixed.top1_mean >= uni.top1_mean - 0.02
            && best_mixed.bits_per_weight < uni.bits_per_weight
        { "ok" } else { "??" },
        100.0 * best_mixed.top1_mean,
        best_mixed.bits_per_weight,
        100.0 * uni.top1_mean,
        uni.bits_per_weight,
    );
    Ok(())
}
