//! Fig. 4 / Fig. 12 reproduction: LeNet-5 on the digits dataset (MNIST
//! substitute) with random-filled M⊕ (Fig. 4) or N_tap=2 M⊕ (Fig. 12), at
//! 0.4 / 0.6 / 0.8 bit/weight via N_out ∈ {10, 20}.
//!
//! Paper claims to reproduce (shape, not absolute numbers):
//!   * training converges even at 0.4 bit/weight;
//!   * larger N_out (20) gives better accuracy + less seed variance than
//!     N_out=10 at the same rate;
//!   * N_tap=2 (Fig. 12) trains at least as well as random fill.
//!
//! ```bash
//! make artifacts SET=full
//! cargo run --release --example fig4_mnist -- --scale 1.0 --seeds 3
//! ```

use anyhow::Result;

use flexor::coordinator::experiments::{print_curves, print_table, run_all, scaled, RunSpec};
use flexor::coordinator::Schedule;
use flexor::runtime::{Manifest, Runtime};
use flexor::substrate::argparse::Args;

fn main() -> Result<()> {
    let a = Args::new("fig4_mnist", "Fig. 4 / Fig. 12: LeNet-5 fractional rates")
        .flag("scale", "step-count scale factor", Some("1.0"))
        .flag("seeds", "seeds per point (paper: 6)", Some("2"))
        .flag("steps", "base steps per run", Some("500"))
        .switch("ntap2", "use the N_tap=2 configs (Fig. 12) instead of random M⊕")
        .parse();
    let scale = a.get_f32("scale");
    let n_seeds = a.get_usize("seeds");
    let steps = scaled(a.get_usize("steps"), scale);
    let seeds: Vec<u64> = (0..n_seeds as u64).collect();
    let tap = if a.get_bool("ntap2") { "tap2" } else { "rand" };

    let sched = Schedule::mnist(1e-3, 100);
    let mk = |label: &str, cfg: &str| {
        RunSpec::new(label, cfg, "digits", steps)
            .schedule(sched.clone())
            .seeds(seeds.clone())
            .eval_every((steps / 8).max(1))
    };

    let specs = vec![
        mk("0.4 b/w (N_in=4, N_out=10)", &format!("fig4_lenet_{tap}_ni4_no10")),
        mk("0.6 b/w (N_in=6, N_out=10)", &format!("fig4_lenet_{tap}_ni6_no10")),
        mk("0.8 b/w (N_in=8, N_out=10)", &format!("fig4_lenet_{tap}_ni8_no10")),
        mk("0.4 b/w (N_in=8, N_out=20)", &format!("fig4_lenet_{tap}_ni8_no20")),
        mk("0.6 b/w (N_in=12, N_out=20)", &format!("fig4_lenet_{tap}_ni12_no20")),
        mk("0.8 b/w (N_in=16, N_out=20)", &format!("fig4_lenet_{tap}_ni16_no20")),
    ];

    let rt = Runtime::cpu()?;
    let man = Manifest::load(std::path::Path::new(flexor::ARTIFACTS_DIR))?;
    let outs = run_all(&rt, &man, &specs)?;

    let fig = if tap == "tap2" { "Fig. 12 (N_tap=2)" } else { "Fig. 4 (random M⊕)" };
    print_table(&format!("{fig} — LeNet-5 on digits"), &outs);
    print_curves(fig, &outs);

    // paper's qualitative claims, checked mechanically:
    let t = |i: usize| outs[i].top1_mean;
    println!("\nclaims:");
    println!(
        "  [{}] all rates train above chance (min top1 {:.1}%)",
        if outs.iter().all(|o| o.top1_mean > 0.2) { "ok" } else { "??" },
        100.0 * outs.iter().map(|o| o.top1_mean).fold(f64::INFINITY, f64::min)
    );
    println!(
        "  [{}] N_out=20 ≥ N_out=10 at 0.4 b/w ({:.1}% vs {:.1}%)",
        if t(3) >= t(0) - 0.02 { "ok" } else { "??" },
        100.0 * t(3),
        100.0 * t(0)
    );
    println!(
        "  [{}] rate ordering at N_out=20: 0.8 ≥ 0.6 ≥ 0.4 ({:.1} / {:.1} / {:.1})",
        if t(5) >= t(4) - 0.02 && t(4) >= t(3) - 0.02 { "ok" } else { "??" },
        100.0 * t(5),
        100.0 * t(4),
        100.0 * t(3)
    );
    Ok(())
}
