//! Fig. 7 / Fig. 16 reproduction: test accuracy across q, N_in, N_out with
//! the warmup recipe. Includes the paper's right-panel observation that
//! 0.8 b/w via (q=1, N_in=8, N_out=10) and via (q=2, N_in=8, N_out=20)
//! land at ≈ the same accuracy ("linear relationship between the number of
//! encrypted weights and model accuracy").
//!
//! ```bash
//! cargo run --release --example fig7_sweep -- --scale 0.5
//! ```

use anyhow::Result;

use flexor::coordinator::experiments::{print_curves, print_table, run_all, scaled, RunSpec};
use flexor::coordinator::Schedule;
use flexor::runtime::{Manifest, Runtime};
use flexor::substrate::argparse::Args;
use flexor::substrate::stats::linreg;

fn main() -> Result<()> {
    let a = Args::new("fig7_sweep", "Fig. 7 / 16: q, N_in, N_out sweep")
        .flag("scale", "step-count scale factor", Some("1.0"))
        .flag("steps", "base steps per run", Some("500"))
        .flag("seeds", "seeds per point (paper: 5 on the right panel)", Some("2"))
        .parse();
    let steps = scaled(a.get_usize("steps"), a.get_f32("scale"));
    let seeds: Vec<u64> = (0..a.get_usize("seeds") as u64).collect();

    // warmup recipe (paper §4 technique 4/5)
    let sched = Schedule::cifar(0.05, 1.0, vec![3.5, 4.5], 100);
    let mk = |label: &str, cfg: &str| {
        RunSpec::new(label, cfg, "shapes32", steps)
            .schedule(sched.clone())
            .seeds(seeds.clone())
            .eval_every((steps / 8).max(1))
    };

    let q1: Vec<RunSpec> = [4usize, 8, 12, 16, 20]
        .iter()
        .map(|ni| mk(&format!("q=1, N_in={ni}, N_out=20 ({:.1} b/w)", *ni as f64 / 20.0),
                     &format!("sweep_q1_ni{ni}_no20")))
        .collect();
    let q2: Vec<RunSpec> = [4usize, 8, 12, 16, 20]
        .iter()
        .map(|ni| mk(&format!("q=2, N_in={ni}, N_out=20 ({:.1} b/w)", 2.0 * *ni as f64 / 20.0),
                     &format!("sweep_q2_ni{ni}_no20")))
        .collect();
    // right panel: two routes to 0.8 b/w
    let equiv = vec![
        mk("0.8 b/w via q=1, N_in=8, N_out=10", "sweep_q1_ni8_no10"),
        mk("0.8 b/w via q=2, N_in=8, N_out=20", "sweep_q2_ni8_no20"),
    ];

    let rt = Runtime::cpu()?;
    let man = Manifest::load(std::path::Path::new(flexor::ARTIFACTS_DIR))?;

    let o1 = run_all(&rt, &man, &q1)?;
    print_table("Fig. 7 (left) — q=1, N_out=20", &o1);
    print_curves("Fig. 7 q=1", &o1);

    let o2 = run_all(&rt, &man, &q2)?;
    print_table("Fig. 16 — q=2, N_out=20", &o2);

    let oe = run_all(&rt, &man, &equiv)?;
    print_table("Fig. 7 (right) — two routes to 0.8 b/w", &oe);

    // accuracy should rise ~monotonically with rate; report the linear fit
    let xs: Vec<f64> = o1.iter().map(|o| o.bits_per_weight).collect();
    let ys: Vec<f64> = o1.iter().map(|o| o.top1_mean).collect();
    let (_, slope, r2) = linreg(&xs, &ys);
    println!("\nclaims:");
    println!(
        "  [{}] accuracy increases with rate (q=1 slope {slope:+.3}/bit, r²={r2:.2})",
        if slope > 0.0 { "ok" } else { "??" }
    );
    let d = (oe[0].top1_mean - oe[1].top1_mean).abs();
    println!(
        "  [{}] the two 0.8 b/w routes agree ({:.1}% vs {:.1}%, Δ={:.1}pp)",
        if d < 0.05 { "ok" } else { "??" },
        100.0 * oe[0].top1_mean,
        100.0 * oe[1].top1_mean,
        100.0 * d
    );
    Ok(())
}
