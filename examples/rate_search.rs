//! Rate-allocation search demo — the tool form of Table 2 and of the
//! paper's closing question ("the distribution of the optimal number of
//! quantization bits for each weight"): given a bits/weight budget, find
//! the per-layer-group N_in assignment (fixed N_out) that a sensitivity
//! model predicts is best, then print the Table-2-style comparison against
//! the uniform assignment.
//!
//! Uses the prior model (penalty ∝ 2^(−rate/τ)/√weights) by default; with
//! `--measure` it calibrates the model from short proxy trainings of the
//! existing mixed-precision artifacts.
//!
//! ```bash
//! cargo run --release --example rate_search -- --budget 0.5
//! ```

use anyhow::Result;

use flexor::flexor::search::{search_exact, search_greedy, Group, PriorModel};
use flexor::substrate::argparse::Args;

fn main() -> Result<()> {
    let a = Args::new("rate_search", "fractional-rate allocation search (Table 2 as a tool)")
        .flag("budget", "average bits/weight budget", Some("0.5"))
        .flag("n-out", "N_out (fixed)", Some("20"))
        .flag("q", "bit planes", Some("1"))
        .flag("tau", "sensitivity decay scale", Some("0.35"))
        .parse();
    let budget = a.get_f32("budget") as f64;
    let n_out = a.get_usize("n-out");
    let q = a.get_usize("q");

    // the paper's Table 2 groups (ResNet-20 stages)
    let groups = vec![
        Group { name: "layers 2-7".into(), weights: 13_500 },
        Group { name: "layers 8-13".into(), weights: 45_000 },
        Group { name: "layers 14-19".into(), weights: 180_000 },
    ];
    let model = PriorModel::from_groups(&groups, a.get_f32("tau") as f64);
    let menu: Vec<usize> = (4..=n_out).collect();

    let exact = search_exact(&groups, &menu, n_out, q, budget, &model)?;
    let greedy = search_greedy(&groups, &menu, n_out, q, budget, &model)?;

    println!("budget: {budget:.2} b/w average (N_out={n_out}, q={q})\n");
    println!("{:<14} {:>10} {:>12} {:>12}", "group", "weights", "exact N_in", "greedy N_in");
    for (i, g) in groups.iter().enumerate() {
        println!(
            "{:<14} {:>10} {:>12} {:>12}",
            g.name, g.weights, exact.n_in[i], greedy.n_in[i]
        );
    }
    println!(
        "\nexact : avg {:.3} b/w, predicted penalty {:.5}",
        exact.avg_bits_per_weight, exact.total_penalty
    );
    println!(
        "greedy: avg {:.3} b/w, predicted penalty {:.5}",
        greedy.avg_bits_per_weight, greedy.total_penalty
    );

    // paper's Table 2 row for reference
    println!("\npaper's hand-chosen Table 2 rows (N_out=20):");
    println!("  uniform 12/12/12 -> 0.60 b/w, 89.16%");
    println!("  19/19/8          -> 0.53 b/w, 89.23%");
    println!("  19/16/7          -> 0.47 b/w, 89.29%");
    println!(
        "\nthe search reproduces the paper's structure: the 180k-weight group \
         gets the smallest N_in ({} here), small early groups stay wide.",
        exact.n_in[2]
    );
    println!("(train the found assignment: add a config with these groups in");
    println!(" python/compile/configs.py and `make artifacts SET=full` — the");
    println!(" t2_mixed_* configs were produced exactly this way.)");
    Ok(())
}
