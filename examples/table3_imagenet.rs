//! Table 3 / Fig. 8 / Table 7 reproduction: the ImageNet-scale experiment
//! on the shapes64 substitute (64×64×3, 20 classes) with the
//! ResNet-18-style architecture (scaled: resnet10img).
//!
//! Reports top-1 / top-5 and the *storage saving* column computed exactly
//! from the FXR container layout. `--q2` adds the appendix Table 7 rows.
//!
//! ```bash
//! cargo run --release --example table3_imagenet -- --scale 0.5 [--q2]
//! ```

use anyhow::Result;

use flexor::coordinator::experiments::{print_table, run_all, scaled, RunSpec};
use flexor::coordinator::Schedule;
use flexor::runtime::{Manifest, Runtime};
use flexor::substrate::argparse::Args;

fn main() -> Result<()> {
    let a = Args::new("table3_imagenet", "Table 3 / Fig. 8: ImageNet-sub compression")
        .flag("scale", "step-count scale factor", Some("1.0"))
        .flag("steps", "base steps per run", Some("400"))
        .flag("seeds", "seeds per point", Some("1"))
        .switch("q2", "add Table 7 (q=2) rows")
        .parse();
    let steps = scaled(a.get_usize("steps"), a.get_f32("scale"));
    let seeds: Vec<u64> = (0..a.get_usize("seeds") as u64).collect();

    // paper §5 recipe: SGD momentum 0.9, warmup 10 epochs of 150ish; scaled
    let sched = Schedule::cifar(0.05, 0.8, vec![2.5, 3.3, 4.0], 100);
    let mk = |label: &str, cfg: &str, paper: Option<f64>| {
        let mut s = RunSpec::new(label, cfg, "shapes64", steps)
            .schedule(sched.clone())
            .seeds(seeds.clone())
            .eval_every((steps / 6).max(1));
        if let Some(p) = paper {
            s = s.paper(p);
        }
        s
    };

    let mut specs = vec![
        mk("Full precision", "t3_img_fp", Some(69.6)),
        mk("BWN (1 bit)", "t3_img_bwn", Some(60.8)),
        mk("BinaryRelax (1 bit)", "t3_img_binaryrelax", Some(63.2)),
        mk("FleXOR (0.8 bit)", "t3_img_f08", Some(63.8)),
        mk("FleXOR (0.63 bit, mixed)", "t3_img_mixed", Some(63.3)),
        mk("FleXOR (0.6 bit)", "t3_img_f06", Some(62.0)),
    ];
    if a.get_bool("q2") {
        specs.push(mk("Ternary TWN-like", "t3_img_ternary", Some(61.8)));
        specs.push(mk("FleXOR q=2 (1.6 bit)", "t3_img_q2_16", Some(66.2)));
        specs.push(mk("FleXOR q=2 (0.8 bit)", "t3_img_q2_08", Some(63.8)));
    }

    let rt = Runtime::cpu()?;
    let man = Manifest::load(std::path::Path::new(flexor::ARTIFACTS_DIR))?;
    let outs = run_all(&rt, &man, &specs)?;
    print_table("Table 3 — ResNet-10img on shapes64 (ImageNet substitute)", &outs);

    println!("\n{:<30} {:>8} {:>8} {:>16}", "method", "top1", "top5", "storage saving");
    for o in &outs {
        let saving = 32.0 / o.bits_per_weight;
        println!(
            "{:<30} {:>7.2}% {:>7.2}% {:>14.1}×",
            o.spec.label,
            100.0 * o.top1_mean,
            100.0 * o.top5_mean,
            saving
        );
    }

    let by = |l: &str| outs.iter().find(|o| o.spec.label.starts_with(l)).map(|o| o.top1_mean);
    println!("\nclaims:");
    if let (Some(f08), Some(bwn)) = (by("FleXOR (0.8"), by("BWN")) {
        println!(
            "  [{}] FleXOR 0.8 b/w ≥ BWN 1 b/w ({:.1}% vs {:.1}%) at 1.25× the saving",
            if f08 >= bwn - 0.02 { "ok" } else { "??" },
            100.0 * f08,
            100.0 * bwn
        );
    }
    if let (Some(f08), Some(f06)) = (by("FleXOR (0.8"), by("FleXOR (0.6 bit")) {
        println!(
            "  [{}] rate ordering 0.8 ≥ 0.6 ({:.1}% vs {:.1}%)",
            if f08 >= f06 - 0.03 { "ok" } else { "??" },
            100.0 * f08,
            100.0 * f06
        );
    }
    Ok(())
}
