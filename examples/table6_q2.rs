//! Table 6 (appendix) reproduction: q=2 multi-bit FleXOR (two independent
//! M⊕ planes) at 1.2 — 2.0 bit/weight vs ternary baselines on shapes32.
//!
//! Paper claims: q=2 FleXOR approaches FP accuracy at 2.0 b/w and stays
//! competitive with ternary (≈1.6 bit) methods below 2 bits.
//!
//! ```bash
//! cargo run --release --example table6_q2 -- --scale 1.0
//! ```

use anyhow::Result;

use flexor::coordinator::experiments::{print_table, run_all, scaled, RunSpec};
use flexor::coordinator::Schedule;
use flexor::runtime::{Manifest, Runtime};
use flexor::substrate::argparse::Args;

fn main() -> Result<()> {
    let a = Args::new("table6_q2", "Table 6: q=2 FleXOR vs ternary")
        .flag("scale", "step-count scale factor", Some("1.0"))
        .flag("steps", "base steps per run", Some("500"))
        .flag("seeds", "seeds per point", Some("2"))
        .parse();
    let steps = scaled(a.get_usize("steps"), a.get_f32("scale"));
    let seeds: Vec<u64> = (0..a.get_usize("seeds") as u64).collect();

    let sched = Schedule::cifar(0.05, 1.0, vec![3.5, 4.5], 100);
    let mk = |label: &str, cfg: &str, paper: Option<f64>| {
        let mut s = RunSpec::new(label, cfg, "shapes32", steps)
            .schedule(sched.clone())
            .seeds(seeds.clone())
            .eval_every((steps / 8).max(1));
        if let Some(p) = paper {
            s = s.paper(p);
        }
        s
    };

    let specs = vec![
        mk("Full precision", "base_r8_fp", Some(91.87)),
        mk("Ternary TWN/TTQ-like", "base_r8_ternary", Some(91.13)),
        mk("q=2, N_in=10, N_out=10 (2.0 b/w)", "sweep_q2_ni10_no10", Some(91.19)),
        mk("q=2, N_in=9, N_out=10 (1.8 b/w)", "sweep_q2_ni9_no10", Some(91.44)),
        mk("q=2, N_in=8, N_out=10 (1.6 b/w)", "sweep_q2_ni8_no10", Some(91.10)),
        mk("q=2, N_in=7, N_out=10 (1.4 b/w)", "sweep_q2_ni7_no10", Some(90.94)),
        mk("q=2, N_in=6, N_out=10 (1.2 b/w)", "sweep_q2_ni6_no10", Some(90.56)),
        mk("q=2, N_in=16, N_out=20 (1.6 b/w)", "sweep_q2_ni16_no20", Some(90.88)),
        mk("q=2, N_in=12, N_out=20 (1.2 b/w)", "sweep_q2_ni12_no20", Some(90.56)),
    ];

    let rt = Runtime::cpu()?;
    let man = Manifest::load(std::path::Path::new(flexor::ARTIFACTS_DIR))?;
    let outs = run_all(&rt, &man, &specs)?;
    print_table("Table 6 — q=2 FleXOR vs ternary (ResNet-8 on shapes32)", &outs);

    let fp = outs[0].top1_mean;
    let q2_20 = outs[2].top1_mean;
    let q2_12 = outs[6].top1_mean;
    println!("\nclaims:");
    println!(
        "  [{}] q=2 @ 2.0 b/w approaches FP (gap {:.1}pp; paper gap 0.68pp)",
        if fp - q2_20 < 0.05 { "ok" } else { "??" },
        100.0 * (fp - q2_20)
    );
    println!(
        "  [{}] rate ordering within q=2: 2.0 ≥ 1.2 b/w ({:.1}% vs {:.1}%)",
        if q2_20 >= q2_12 - 0.03 { "ok" } else { "??" },
        100.0 * q2_20,
        100.0 * q2_12
    );
    Ok(())
}
