//! Deployment-path example: train briefly, export the encrypted bundle,
//! then run a batched "inference service" loop entirely in Rust —
//! decrypting stored bits through the word-parallel XOR engine at load
//! time and serving requests with the binary-code forward — reporting
//! latency percentiles and throughput (the serving-side view of Fig. 1).
//!
//! ```bash
//! cargo run --release --example serve -- --requests 200 --batch 16
//! ```

use std::time::Instant;

use anyhow::Result;

use flexor::coordinator::{export_bundle, MetricsSink, Schedule, TrainSession};
use flexor::data::{self, Batcher, Split};
use flexor::inference::InferenceModel;
use flexor::runtime::{Manifest, Runtime};
use flexor::substrate::argparse::Args;
use flexor::substrate::stats::percentiles;

fn main() -> Result<()> {
    let a = Args::new("serve", "encrypted-bundle inference service demo")
        .flag("train-steps", "steps before export", Some("200"))
        .flag("requests", "number of request batches", Some("100"))
        .flag("batch", "examples per request", Some("16"))
        .flag("artifact", "config to train/export", Some("quickstart_mlp"))
        .flag("dataset", "request generator", Some("digits"))
        .parse();

    // 1. train + export the encrypted bundle
    let rt = Runtime::cpu()?;
    let man = Manifest::load(std::path::Path::new(flexor::ARTIFACTS_DIR))?;
    let mut session = TrainSession::new(&rt, &man, a.get("artifact"))?;
    let ds = data::by_name(a.get("dataset"), 0)?;
    let mut sink = MetricsSink::new();
    let steps = a.get_usize("train-steps");
    let sched = Schedule::mnist(1e-3, 100);
    let ev = session.train_loop(ds.as_ref(), &sched, steps, steps, 256, &mut sink)?;
    let dir = std::path::Path::new("runs/serve");
    export_bundle(&session, dir, "served")?;
    println!(
        "trained {} steps (eval top1 {:.1}%), exported encrypted bundle",
        steps,
        100.0 * ev.top1
    );

    // 2. load the bundle: decryption happens once here (measure it)
    let t_load = Instant::now();
    let model = InferenceModel::load(dir, "served")?;
    let load_ms = t_load.elapsed().as_secs_f64() * 1e3;
    println!(
        "loaded + decrypted in {load_ms:.1} ms  ({:.2} b/w, {:.1}× compression)",
        model.bits_per_weight, model.compression_ratio
    );

    // 3. serve request batches, measure latency distribution
    let n_req = a.get_usize("requests");
    let bsz = a.get_usize("batch");
    let (xs, ys) = Batcher::eval_set(ds.as_ref(), Split::Test, n_req * bsz);
    let fl = ds.feature_len();
    let mut lat = Vec::with_capacity(n_req);
    let mut correct = 0usize;
    let t_all = Instant::now();
    for r in 0..n_req {
        let req = &xs[r * bsz * fl..(r + 1) * bsz * fl];
        let t0 = Instant::now();
        let preds = model.predict(req, bsz)?;
        lat.push(t0.elapsed().as_secs_f64() * 1e3);
        correct += preds
            .iter()
            .zip(&ys[r * bsz..(r + 1) * bsz])
            .filter(|(p, y)| p == y)
            .count();
    }
    let total_s = t_all.elapsed().as_secs_f64();
    let ps = percentiles(lat.clone(), &[50.0, 95.0, 99.0]);
    println!("\nserved {n_req} requests × {bsz} examples:");
    println!("  accuracy      : {:.2}%", 100.0 * correct as f64 / (n_req * bsz) as f64);
    println!("  latency p50   : {:.2} ms/request", ps[0]);
    println!("  latency p95   : {:.2} ms", ps[1]);
    println!("  latency p99   : {:.2} ms", ps[2]);
    println!("  throughput    : {:.0} examples/s", (n_req * bsz) as f64 / total_s);
    Ok(())
}
