//! Deployment-path example, now on the real serving subsystem: export an
//! encrypted bundle (training it first when AOT artifacts are available,
//! else synthesizing one), host it in the multi-threaded batched server
//! (`flexor::serve`), hammer it with N concurrent HTTP client threads,
//! and report the latency percentile table plus the server-side batching
//! metrics — the serving-side view of Fig. 1 under actual concurrency.
//!
//! ```bash
//! cargo run --release --example serve -- --requests 256 --clients 8
//! ```

use std::path::Path;
use std::thread;
use std::time::Instant;

use anyhow::{Context, Result};

use flexor::coordinator::{
    export_bundle, export_synthetic_mlp_bundle, MetricsSink, Schedule, TrainSession,
};
use flexor::data::{self, Batcher, Split};
use flexor::inference::ModePolicy;
use flexor::runtime::{Manifest, Runtime};
use flexor::serve::{http, Registry, ServeConfig, Server};
use flexor::substrate::argparse::Args;
use flexor::substrate::bench::{merge_bench_history, merge_bench_json};
use flexor::substrate::json::{self, Json};
use flexor::substrate::stats::percentiles;

fn main() -> Result<()> {
    let a = Args::new("serve", "batched encrypted-bundle inference server demo")
        .flag("train-steps", "steps before export (with artifacts)", Some("200"))
        .flag("requests", "total single-example requests", Some("256"))
        .flag("clients", "concurrent client threads", Some("8"))
        .switch("keep-alive", "one persistent connection per client (event-loop concurrency smoke)")
        .flag("workers", "server worker threads", Some("2"))
        .flag("intra-threads", "GEMM threads per forward (0 = auto)", Some("0"))
        .flag("max-batch", "max coalesced batch size", Some("16"))
        .flag("max-wait-us", "batching linger window (µs)", Some("2000"))
        .flag("compute-mode",
              "policy <mode>[@min=<w>][,<idx>=<mode>]*, mode = dense | bitplane[:<m>] | encrypted[:<m>] (default: FLEXOR_COMPUTE env, else dense)",
              Some(""))
        .flag("artifact", "config to train/export", Some("quickstart_mlp"))
        .flag("dataset", "request generator", Some("digits"))
        .parse();

    // per-layer compute policy the registry loads bundles onto:
    // explicit flag wins, else FLEXOR_COMPUTE, else dense
    let policy = match a.get("compute-mode") {
        "" => ModePolicy::default_from_env()?,
        s => ModePolicy::parse(s)?,
    };
    let cfg = ServeConfig {
        workers: a.get_usize("workers"),
        intra_threads: a.get_usize("intra-threads"),
        max_batch: a.get_usize("max-batch"),
        max_wait_us: a.get_u64("max-wait-us"),
        ..ServeConfig::default()
    };

    let dir = Path::new("runs/serve");
    let ds = data::by_name(a.get("dataset"), 0)?;

    // 1. produce an encrypted bundle. With AOT artifacts *and* a working
    //    PJRT runtime: train briefly and export the real thing. Otherwise
    //    (fresh checkout, CI, vendored xla stub): a seeded synthetic
    //    bundle exercises the identical serving path.
    let artifacts = Path::new(flexor::ARTIFACTS_DIR);
    let mut trained = false;
    if artifacts.join("manifest.json").exists() {
        match Runtime::cpu() {
            Ok(rt) => {
                let man = Manifest::load(artifacts)?;
                let mut session = TrainSession::new(&rt, &man, a.get("artifact"))?;
                let mut sink = MetricsSink::new();
                let steps = a.get_usize("train-steps");
                let sched = Schedule::mnist(1e-3, 100);
                let ev =
                    session.train_loop(ds.as_ref(), &sched, steps, steps, 256, &mut sink)?;
                export_bundle(&session, dir, "served")?;
                println!(
                    "trained {} steps (eval top1 {:.1}%), exported encrypted bundle",
                    steps,
                    100.0 * ev.top1
                );
                trained = true;
            }
            Err(e) => println!("PJRT runtime unavailable ({e:#})"),
        }
    }
    if !trained {
        println!("serving a synthetic mlp bundle instead (random weights)");
        export_synthetic_mlp_bundle(dir, "served", 0, ds.feature_len(), &[64, 32],
                                    ds.num_classes())?;
    }

    // 2. load into the registry: XOR decryption happens once, here.
    //    Bit-plane layers stay packed bit-plane panels for their whole
    //    serving lifetime (DESIGN.md §8/§9); a mixed policy keeps small
    //    layers FP-exact.
    let registry = Registry::with_default_policy(policy);
    let entry = registry.load("served", dir, "served")?;
    println!(
        "loaded + decrypted in {:.1} ms  ({:.2} b/w, {:.1}× compression)",
        entry.load_ms, entry.model.bits_per_weight, entry.model.compression_ratio
    );
    println!(
        "compute mode {} (simd kernel {}): {} quantized weight bytes resident (+{} FP residue)",
        entry.model.mode_label(),
        flexor::inference::bitslice::popcount::active().label(),
        entry.model.quantized_resident_bytes(),
        entry.model.fp_resident_bytes()
    );
    if entry.model.is_mixed() {
        for lm in entry.model.layer_modes() {
            println!("  layer {:>2}: {:8} ({} weights)", lm.idx, lm.mode.label(), lm.weights);
        }
    }

    // 3. start the server on an ephemeral loopback port
    let server = Server::start("127.0.0.1:0", registry, cfg)?;
    let addr = server.local_addr();
    println!(
        "serving on http://{addr}  ({} workers × {} GEMM threads, max_batch {}, max_wait {} µs)",
        cfg.workers,
        flexor::substrate::pool::global().threads(),
        cfg.max_batch,
        cfg.max_wait_us
    );

    // 4. concurrent clients fire single-example POST /predict requests.
    //    With --keep-alive each client holds ONE persistent connection for
    //    all its requests — `clients` sockets stay simultaneously open
    //    against the event-loop front-end (the CI concurrency smoke runs
    //    this with 512 clients).
    let keep_alive = a.get_bool("keep-alive");
    let clients = a.get_usize("clients").max(1);
    let per_client = (a.get_usize("requests") / clients).max(1);
    let total = clients * per_client;
    let fl = ds.feature_len();
    let (xs, ys) = Batcher::eval_set(ds.as_ref(), Split::Test, total);

    let t_all = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            let lo = c * per_client;
            let feats: Vec<Vec<f32>> = (lo..lo + per_client)
                .map(|i| xs[i * fl..(i + 1) * fl].to_vec())
                .collect();
            let labels = ys[lo..lo + per_client].to_vec();
            thread::spawn(move || -> Result<(Vec<f64>, usize)> {
                let mut conn =
                    if keep_alive { Some(http::client::Conn::connect(addr)?) } else { None };
                let mut lat = Vec::with_capacity(feats.len());
                let mut correct = 0usize;
                for (x, &y) in feats.iter().zip(&labels) {
                    let body = Json::obj(vec![
                        ("model", Json::str("served")),
                        ("features", Json::arr(x.iter().map(|&v| Json::num(v)))),
                    ])
                    .to_string();
                    let t0 = Instant::now();
                    let (status, resp) = match conn.as_mut() {
                        Some(c) => c.request("POST", "/predict", Some(&body))?,
                        None => http::client::request(addr, "POST", "/predict", Some(&body))?,
                    };
                    lat.push(t0.elapsed().as_secs_f64() * 1e3);
                    anyhow::ensure!(status == 200, "predict failed ({status}): {resp}");
                    let pred = json::parse(&resp)?
                        .get("prediction")
                        .as_i64()
                        .context("response missing 'prediction'")?;
                    correct += (pred as i32 == y) as usize;
                }
                Ok((lat, correct))
            })
        })
        .collect();

    let mut lat = Vec::with_capacity(total);
    let mut correct = 0usize;
    for h in handles {
        let (l, c) = h.join().expect("client thread panicked")?;
        lat.extend(l);
        correct += c;
    }
    let total_s = t_all.elapsed().as_secs_f64();

    // 5. client-side percentile table (same shape as the old demo)
    let ps = percentiles(&lat, &[50.0, 95.0, 99.0]);
    println!("\nserved {total} requests from {clients} concurrent clients:");
    println!("  accuracy      : {:.2}%", 100.0 * correct as f64 / total as f64);
    println!("  latency p50   : {:.2} ms/request", ps[0]);
    println!("  latency p95   : {:.2} ms", ps[1]);
    println!("  latency p99   : {:.2} ms", ps[2]);
    println!("  throughput    : {:.0} requests/s", total as f64 / total_s);

    // 6. server-side view: how well did the admission queue coalesce?
    let (status, m) = http::client::request(addr, "GET", "/metrics", None)?;
    anyhow::ensure!(status == 200, "metrics failed: {m}");
    let mj = json::parse(&m)?;
    println!(
        "  batching      : {:.2} examples/forward over {} forwards (server p99 {:.2} ms)",
        mj.get("mean_batch_size").as_f64().unwrap_or(0.0),
        mj.get("batches_total").as_usize().unwrap_or(0),
        mj.get("latency_ms").get("p99").as_f64().unwrap_or(0.0),
    );
    if keep_alive {
        println!(
            "  connections   : {} accepted, {} keep-alive reuses",
            mj.get("connections_total").as_usize().unwrap_or(0),
            mj.get("keepalive_requests_total").as_usize().unwrap_or(0),
        );
        // record the concurrency result next to the bench trajectory so
        // the CI smoke's 512-connection run lands in BENCH_infer.json
        let mode = std::env::var("FLEXOR_HTTP_MODE").unwrap_or_else(|_| "event_loop".into());
        let recs = Json::arr(vec![Json::obj(vec![
            ("name", Json::str("concurrent_connections_p99_ms")),
            ("http_mode", Json::str(mode)),
            ("connections", Json::num(clients as f64)),
            ("requests", Json::num(total as f64)),
            ("p50_ms", Json::num(ps[0])),
            ("p99_ms", Json::num(ps[2])),
            ("throughput_rps", Json::num(total as f64 / total_s)),
        ])]);
        let _ = merge_bench_json(
            Path::new("BENCH_infer.json"),
            "serve_concurrency",
            recs.clone(),
        );
        let _ = merge_bench_history("serve_concurrency", recs);
    }

    // 7. observability endpoints: the Prometheus exposition and the
    //    per-layer profile (populated when FLEXOR_TRACE samples forwards)
    let (status, prom) =
        http::client::request(addr, "GET", "/metrics?format=prometheus", None)?;
    anyhow::ensure!(
        status == 200 && prom.contains("flexor_requests_total"),
        "prometheus exposition failed ({status})"
    );
    let metric_lines =
        prom.lines().filter(|l| !l.starts_with('#') && !l.is_empty()).count();
    println!("  prometheus    : {metric_lines} metric lines exposed");

    let (status, prof) = http::client::request(addr, "GET", "/models/served/profile", None)?;
    anyhow::ensure!(status == 200, "profile endpoint failed ({status}): {prof}");
    let pj = json::parse(&prof)?;
    let traced = pj.get("traced_forwards").as_usize().unwrap_or(0);
    let layers = pj.get("layers").as_arr().map(|a| a.len()).unwrap_or(0);
    println!(
        "  profile       : {traced} traced forwards, {layers} layers (trace mode {})",
        pj.get("trace_mode").as_str().unwrap_or("?")
    );

    server.shutdown();
    Ok(())
}
