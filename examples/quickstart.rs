//! Quickstart: train a FleXOR-quantized MLP (0.8 bit/weight) on the
//! procedural digits dataset, export the encrypted deployment bundle, and
//! run the pure-Rust decrypted inference path — the whole paper pipeline
//! in one binary.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use std::path::Path;

use anyhow::Result;

use flexor::coordinator::{export_bundle, MetricsSink, Schedule, TrainSession};
use flexor::data::{self, Batcher, Split};
use flexor::inference::InferenceModel;
use flexor::runtime::{Manifest, Runtime};

fn main() -> Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);

    // 1. load the AOT artifact (lowered once by `make artifacts`)
    let rt = Runtime::cpu()?;
    let manifest = Manifest::load(Path::new(flexor::ARTIFACTS_DIR))?;
    let mut session = TrainSession::new(&rt, &manifest, "quickstart_mlp")?;
    println!(
        "artifact: {} | model {} | quantizer {} @ {:.2} bit/weight",
        session.meta.name, session.meta.model, session.meta.quantizer_kind,
        session.meta.bits_per_weight
    );

    // 2. train on procedural digits (MNIST substitute), Adam + constant
    //    S_tanh=100 — the paper's §3 MNIST recipe
    let ds = data::by_name("digits", 0)?;
    let schedule = Schedule::mnist(1e-3, 100);
    let mut sink = MetricsSink::new();
    let ev = session.train_loop(ds.as_ref(), &schedule, steps, 50, 512, &mut sink)?;
    println!("\nloss curve (every 25 steps):");
    for row in sink.train.iter().step_by(25) {
        println!("  step {:>5}  loss {:.4}  acc {:.3}", row.step, row.loss, row.acc);
    }
    println!(
        "\nfinal eval: loss {:.4}  top1 {:.2}%  ({} examples)",
        ev.loss, 100.0 * ev.top1, ev.examples
    );

    // 3. export the encrypted deployment bundle (.fxr + FP sidecar)
    let out = Path::new("runs/quickstart");
    export_bundle(&session, out, "quickstart_mlp")?;
    let bundle_json =
        std::fs::read_to_string(out.join("quickstart_mlp.bundle.json"))?;
    println!("\nexported bundle:\n{bundle_json}");

    // 4. deployment path: decrypt with word-parallel XOR gates, run the
    //    pure-Rust forward, compare against the training-side eval accuracy
    let model = InferenceModel::load(out, "quickstart_mlp")?;
    let n = 256;
    let (xs, ys) = Batcher::eval_set(ds.as_ref(), Split::Test, n);
    let preds = model.predict(&xs, n)?;
    let correct = preds.iter().zip(&ys).filter(|(p, y)| p == y).count();
    println!(
        "rust inference (decrypted bits): top1 {:.2}%  vs HLO eval {:.2}%",
        100.0 * correct as f64 / n as f64,
        100.0 * ev.top1
    );
    Ok(())
}
