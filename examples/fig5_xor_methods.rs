//! Fig. 5 reproduction: XOR training method ablation on ResNet (shapes32),
//! 0.8 bit/weight — STE vs "analog" (tanh fwd+bwd, STE binarize) vs FleXOR
//! (sign fwd, ∂tanh bwd), plus the Eq. (5) exact-tanh gradient variant.
//!
//! Paper claim: FleXOR's (sign fwd, ∂tanh bwd) combination wins.
//!
//! ```bash
//! cargo run --release --example fig5_xor_methods -- --scale 1.0
//! ```

use anyhow::Result;

use flexor::coordinator::experiments::{print_curves, print_table, run_all, scaled, RunSpec};
use flexor::coordinator::Schedule;
use flexor::runtime::{Manifest, Runtime};
use flexor::substrate::argparse::Args;

fn main() -> Result<()> {
    let a = Args::new("fig5_xor_methods", "Fig. 5: XOR training method ablation")
        .flag("scale", "step-count scale factor", Some("1.0"))
        .flag("seeds", "seeds per point", Some("2"))
        .flag("steps", "base steps per run", Some("500"))
        .parse();
    let steps = scaled(a.get_usize("steps"), a.get_f32("scale"));
    let seeds: Vec<u64> = (0..a.get_usize("seeds") as u64).collect();

    // paper recipe: SGD momentum, S_tanh=10 (runtime scalar), lr 0.1-style
    let sched = Schedule {
        s_tanh_start: 10.0,
        s_tanh_base: 10.0,
        ..Schedule::cifar(0.05, 0.5, vec![3.0, 4.0], 100)
    };
    let mk = |label: &str, cfg: &str| {
        RunSpec::new(label, cfg, "shapes32", steps)
            .schedule(sched.clone())
            .seeds(seeds.clone())
            .eval_every((steps / 8).max(1))
    };
    let specs = vec![
        mk("STE (sign fwd, identity bwd)", "fig5_ste"),
        mk("Analog (tanh fwd+bwd, STE out)", "fig5_analog"),
        mk("FleXOR (sign fwd, ∂tanh bwd)", "fig5_flexor"),
        mk("FleXOR + Eq.(5) exact grads", "fig5_exactgrad"),
    ];

    let rt = Runtime::cpu()?;
    let man = Manifest::load(std::path::Path::new(flexor::ARTIFACTS_DIR))?;
    let outs = run_all(&rt, &man, &specs)?;
    print_table("Fig. 5 — XOR training methods (ResNet-8, 0.8 b/w)", &outs);
    print_curves("Fig. 5", &outs);

    let flexor_t1 = outs[2].top1_mean;
    let best_other = outs[0].top1_mean.max(outs[1].top1_mean);
    println!(
        "\nclaims:\n  [{}] FleXOR ≥ STE and analog ({:.1}% vs best-other {:.1}%)",
        if flexor_t1 >= best_other - 0.02 { "ok" } else { "??" },
        100.0 * flexor_t1,
        100.0 * best_other
    );
    Ok(())
}
