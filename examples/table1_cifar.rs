//! Table 1 reproduction: weight compression on the CIFAR substitute
//! (shapes32) — FleXOR at 1.0 / 0.8 / 0.6 / 0.4 bit/weight against the FP
//! reference and the reimplemented baselines (BWN, BinaryRelax, ternary,
//! DSQ-like).
//!
//! Shape targets (paper Table 1):
//!   * FleXOR(1.0) beats BWN/BinaryRelax at 1 bit;
//!   * accuracy degrades gracefully as the rate drops to 0.4;
//!   * even 0.4 b/w stays far above chance.
//!
//! ```bash
//! cargo run --release --example table1_cifar -- --scale 1.0 [--model r14]
//! ```

use anyhow::Result;

use flexor::coordinator::experiments::{print_table, run_all, scaled, RunSpec};
use flexor::coordinator::Schedule;
use flexor::runtime::{Manifest, Runtime};
use flexor::substrate::argparse::Args;

fn main() -> Result<()> {
    let a = Args::new("table1_cifar", "Table 1: compression comparison")
        .flag("scale", "step-count scale factor", Some("1.0"))
        .flag("steps", "base steps per run", Some("500"))
        .flag("seeds", "seeds per point", Some("2"))
        .flag("model", "r8 (ResNet-20 analogue) or r14 (ResNet-32 analogue)", Some("r8"))
        .parse();
    let steps = scaled(a.get_usize("steps"), a.get_f32("scale"));
    let seeds: Vec<u64> = (0..a.get_usize("seeds") as u64).collect();
    let m = a.get("model").to_string();
    let paper_col = if m == "r8" {
        // paper's ResNet-20 column
        [("fp", 91.87), ("bwn", 87.44), ("binaryrelax", 87.82),
         ("f10", 90.44), ("f08", 89.91), ("f06", 89.16), ("f04", 88.23)]
    } else {
        // paper's ResNet-32 column
        [("fp", 92.33), ("bwn", 89.49), ("binaryrelax", 90.65),
         ("f10", 91.36), ("f08", 91.20), ("f06", 90.43), ("f04", 89.61)]
    };
    let paper = |k: &str| paper_col.iter().find(|(n, _)| *n == k).map(|(_, v)| *v);

    let sched = Schedule::cifar(0.05, 1.0, vec![3.5, 4.5], 100);
    let mk = |label: &str, cfg: String, pk: &str| {
        let mut s = RunSpec::new(label, &cfg, "shapes32", steps)
            .schedule(sched.clone())
            .seeds(seeds.clone())
            .eval_every((steps / 8).max(1));
        if let Some(p) = paper(pk) {
            s = s.paper(p);
        }
        s
    };

    let specs = vec![
        mk("Full precision", format!("base_{m}_fp"), "fp"),
        mk("BWN (1 bit)", format!("base_{m}_bwn"), "bwn"),
        mk("BinaryRelax (1 bit)", format!("base_{m}_binaryrelax"), "binaryrelax"),
        mk("Ternary TWN/TTQ-like (2 bit)", format!("base_{m}_ternary"), ""),
        mk("DSQ-like (1 bit)", format!("base_{m}_dsq"), ""),
        mk("FleXOR (1.0 bit)", format!("t1_{m}_f10"), "f10"),
        mk("FleXOR (0.8 bit)", format!("t1_{m}_f08"), "f08"),
        mk("FleXOR (0.6 bit)", format!("t1_{m}_f06"), "f06"),
        mk("FleXOR (0.4 bit)", format!("t1_{m}_f04"), "f04"),
    ];

    let rt = Runtime::cpu()?;
    let man = Manifest::load(std::path::Path::new(flexor::ARTIFACTS_DIR))?;
    let outs = run_all(&rt, &man, &specs)?;
    let arch = if m == "r8" { "ResNet-8 (ResNet-20 analogue)" } else { "ResNet-14 (ResNet-32 analogue)" };
    print_table(&format!("Table 1 — {arch} on shapes32"), &outs);

    // mechanical shape checks
    let by = |l: &str| outs.iter().find(|o| o.spec.label.starts_with(l)).unwrap().top1_mean;
    let (fp, bwn, f10, f08, f06, f04) = (
        by("Full"), by("BWN"), by("FleXOR (1.0"), by("FleXOR (0.8"),
        by("FleXOR (0.6"), by("FleXOR (0.4"),
    );
    println!("\nclaims:");
    println!("  [{}] FleXOR(1.0) ≥ BWN at the same compute ({:.1}% vs {:.1}%)",
             if f10 >= bwn - 0.02 { "ok" } else { "??" }, 100.0 * f10, 100.0 * bwn);
    println!("  [{}] graceful degradation 1.0 ≥ 0.8 ≥ 0.6 ≥ 0.4 ({:.1}/{:.1}/{:.1}/{:.1})",
             if f10 >= f08 - 0.03 && f08 >= f06 - 0.03 && f06 >= f04 - 0.03 { "ok" } else { "??" },
             100.0 * f10, 100.0 * f08, 100.0 * f06, 100.0 * f04);
    println!("  [{}] FP is the upper bound ({:.1}%)",
             if fp >= f10 - 0.02 { "ok" } else { "??" }, 100.0 * fp);
    Ok(())
}
